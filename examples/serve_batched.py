"""End-to-end serving driver: a continuous-batching engine over a small
model with dense vs SWAN-compressed KV caches.

    PYTHONPATH=src python examples/serve_batched.py [--no-swan] [--k 8]
                                                    [--buffer 16] [--quantize]
                                                    [--slots 4] [--requests 8]

New API (this used to be a lockstep ``ServeSession`` demo): requests with
*mixed prompt lengths* are submitted to ``repro.runtime.serve_engine.
ServeEngine``, which admits them into cache slots as capacity frees up and
decodes all active sequences in one jitted step with per-sequence
positions.  The SWAN run additionally cycles *per-request* compression
levels k — the paper's runtime-tunable knob — through a single compiled
decode executable.  Reported: wall-clock throughput, scheduler steps, and
physical cache bytes (paper Eq. 1) for dense vs SWAN on the same requests.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-swan", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="also run the paged sparse cache (memory follows "
                         "live tokens — see repro.core.paged_cache)")
    ap.add_argument("--page-size", type=int, default=32)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: power-of-two tokens per chunk — "
                         "bounded prefill work per engine step, so long "
                         "admissions never stall active decodes")
    ap.add_argument("--prefill-slots", type=int, default=1,
                    help="batched concurrent prefill: up to P in-flight "
                         "prefills advance per step, packed into ONE "
                         "multi-slot chunk dispatch (cuts TTFT under "
                         "admission bursts; requires --prefill-chunk)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="per-step prefill token budget round-robined "
                         "across in-flight prefills (default: "
                         "prefill-slots * prefill-chunk)")
    ap.add_argument("--data-parallel", type=int, default=None,
                    help="shard slots, caches and the paged pool over a "
                         "('data',) mesh of this many devices (run with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to simulate a mesh on CPU; --slots must "
                         "divide)")
    ap.add_argument("--use-pallas", action="store_true", default=None,
                    help="force the Pallas kernel-backed decode/chunk "
                         "attention read on the SWAN engines (default: "
                         "auto — compiled kernels on TPU, pure-JAX "
                         "elsewhere; forcing on CPU uses the interpreter)")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--buffer", type=int, default=16)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=48)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--stats-every", type=int, default=0,
                    help="print a periodic stats line every N engine steps "
                         "(queue depth, active lanes, tokens, live cache "
                         "bytes, TTFT p50 — read off engine.metrics)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile each engine's full executable family "
                         "before its first request (repro.runtime.warmup) "
                         "— the bench timings then contain zero JIT cost")
    ap.add_argument("--async-fetch", action="store_true",
                    help="overlap host scheduling with the decode token "
                         "transfer (token-identical to the sync path)")
    args = ap.parse_args()
    if ((args.prefill_slots > 1 or args.prefill_budget is not None)
            and not args.prefill_chunk):
        raise SystemExit("--prefill-slots/--prefill-budget require "
                         "--prefill-chunk")
    mesh = None
    if args.data_parallel:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.data_parallel)

    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_head=16,
        d_ff=256, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    def requests(k_cycle):
        out = []
        for i in range(args.requests):
            plen = max(4, args.prompt_len - 5 * (i % 4))   # mixed lengths
            toks = make_batch(cfg, 1, plen, seed=100 + i)["tokens"][0]
            out.append(Request(uid=f"req{i}", tokens=[int(t) for t in toks],
                               max_new_tokens=args.gen_tokens,
                               k=k_cycle[i % len(k_cycle)]))
        return out

    def stats_line(engine, tag):
        m = engine.metrics
        ttft = m.get("serve_ttft_steps")
        p50 = (f"{ttft.quantile(0.5):.0f}" if ttft is not None and ttft.count
               else "-")
        print(f"[{tag:>6}] step {engine.step_count:4d} | "
              f"queue {m.value('serve_queue_depth'):3.0f} "
              f"lanes {m.value('serve_lanes_active'):2.0f} | "
              f"tokens {m.value('serve_tokens_generated_total'):5.0f} | "
              f"live cache {m.value('kv_cache_live_bytes') / 1e6:6.2f} MB | "
              f"ttft p50 ~{p50} steps")

    def bench(engine, reqs, tag):
        if args.warmup:
            rep = engine.warmup(max_prompt_len=args.prompt_len)
            print(f"[{tag:>6}] warmup: {rep['census']['total']} executables"
                  f", {rep['compiles']} compiles in "
                  f"{rep['warmup_ms']:.0f} ms")
        t0 = time.perf_counter()
        if args.stats_every > 0:
            # step manually so we can read the per-step gauges mid-flight
            for r in reqs:
                engine.submit(r)
            comps0 = len(engine.completions)
            while not engine.done:
                engine.step()
                if engine.step_count % args.stats_every == 0:
                    stats_line(engine, tag)
            comps = engine.completions[comps0:]
        else:
            comps = engine.run(reqs)
        dt = time.perf_counter() - t0
        n_tok = sum(len(c.tokens) for c in comps)
        rep = engine.cache_report()
        print(f"[{tag:>6}] {len(comps)} reqs, {n_tok} tokens in "
              f"{dt * 1e3:7.1f} ms ({n_tok / dt:7.1f} tok/s, "
              f"{engine.step_count} steps) | cache {rep['bytes'] / 1e6:6.2f} MB"
              + (f" ({rep['saving']:.0%} saved)" if "saving" in rep else ""))

    dense = ServeEngine(cfg, params, max_seq=args.max_seq, n_slots=args.slots,
                        prefill_chunk=args.prefill_chunk,
                        prefill_slots=args.prefill_slots,
                        prefill_budget=args.prefill_budget, mesh=mesh,
                        async_fetch=args.async_fetch)
    bench(dense, requests([None]), "dense")

    if not args.no_swan:
        projections = calibrate_swan(api, cfg, params,
                                     make_batch(cfg, 4, 64, seed=3))
        absorbed = api.absorb(params, cfg, projections)
        k_max = args.k or cfg.d_head // 2
        swan = SwanConfig(k_max=k_max, buffer=args.buffer, mode="topk",
                          quantize=args.quantize)
        eng = ServeEngine(cfg, absorbed, swan=swan, projections=projections,
                          max_seq=args.max_seq, n_slots=args.slots,
                          prefill_chunk=args.prefill_chunk,
                          prefill_slots=args.prefill_slots,
                          prefill_budget=args.prefill_budget, mesh=mesh,
                          use_pallas=args.use_pallas,
                          async_fetch=args.async_fetch)
        # per-request runtime-tunable compression: mix full and half k
        bench(eng, requests([k_max, max(k_max // 2, 1)]), "swan")
        print(f"        decode executables for the mixed-k batch: "
              f"{eng.decode_cache_size}")
        if args.paged:
            pg = ServeEngine(cfg, absorbed, swan=swan,
                             projections=projections, max_seq=args.max_seq,
                             n_slots=args.slots, paged=True,
                             page_size=args.page_size,
                             prefill_chunk=args.prefill_chunk,
                             prefill_slots=args.prefill_slots,
                             prefill_budget=args.prefill_budget, mesh=mesh,
                             use_pallas=args.use_pallas,
                             async_fetch=args.async_fetch)
            bench(pg, requests([k_max, max(k_max // 2, 1)]), "paged")
            rep = pg.cache_report()
            print(f"        paged: slab layout would reserve "
                  f"{rep['slab_bytes'] / 1e6:.2f} MB; pool live bytes "
                  f"followed generated tokens (now drained: "
                  f"{rep['live_pages']} pages)")


if __name__ == "__main__":
    main()
