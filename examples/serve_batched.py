"""End-to-end serving driver: batched requests against a small model with a
SWAN-compressed KV cache, with throughput + memory reporting.

    PYTHONPATH=src python examples/serve_batched.py [--swan/--no-swan]
                                                    [--k 16] [--buffer 16]
                                                    [--quantize] [--batch 8]

This is the paper-kind end-to-end example (SWAN is an inference technique):
prefill a batch of prompts, decode autoregressively, compare dense vs
compressed serving on the same prompts.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_loop import ServeSession, calibrate_swan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-swan", action="store_true")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--buffer", type=int, default=16)
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=48)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_head=16,
        d_ff=256, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompts = make_batch(cfg, args.batch, args.prompt_len, seed=11)

    def bench(sess, tag):
        t0 = time.perf_counter()
        sess.prefill(prompts)
        t_prefill = time.perf_counter() - t0
        tok = jnp.zeros((args.batch,), jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.gen_tokens):
            logits = sess.decode(tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        rep = sess.cache_report()
        tput = args.batch * args.gen_tokens / t_decode
        print(f"[{tag:>6}] prefill {t_prefill * 1e3:7.1f} ms | "
              f"decode {t_decode * 1e3:7.1f} ms ({tput:7.1f} tok/s) | "
              f"cache {rep['bytes'] / 1e6:6.2f} MB"
              + (f" ({rep['saving']:.0%} saved)" if "saving" in rep else ""))

    dense = ServeSession(cfg, params, max_seq=args.max_seq, batch=args.batch)
    bench(dense, "dense")

    if not args.no_swan:
        projections = calibrate_swan(api, cfg, params,
                                     make_batch(cfg, 4, 64, seed=3))
        absorbed = api.absorb(params, cfg, projections)
        swan = SwanConfig(k_max=args.k or cfg.d_head // 2,
                          buffer=args.buffer, mode="topk",
                          quantize=args.quantize)
        sess = ServeSession(cfg, absorbed, swan=swan,
                            projections=projections,
                            max_seq=args.max_seq, batch=args.batch)
        bench(sess, "swan")


if __name__ == "__main__":
    main()
