"""Long-context decode with runtime-tunable compression.

    PYTHONPATH=src python examples/long_context_decode.py

Demonstrates the paper's operational claim: the SAME deployed weights serve
at several compression levels — the runtime knobs (k_key/k_value <= k_max)
change per session with no offline reconfiguration — and shows how the
hybrid cache keeps whole-context information (vs token eviction) by probing
recall of early-context tokens late in decode.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import SwanConfig, get_smoke_config
from repro.core.analytical import model_cache_footprint
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_loop import ServeSession, calibrate_swan


def main():
    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
        d_ff=192, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    projections = calibrate_swan(api, cfg, params, make_batch(cfg, 4, 64))
    absorbed = api.absorb(params, cfg, projections)

    long_prompt = make_batch(cfg, 1, 384, seed=5)

    def decode_tail(sess, n=12):
        """Prefill then greedy-decode n tokens — decode reads the
        (compressed) cache, so compression error shows up here (prefill
        logits alone are lossless by Lemma A.1)."""
        logits = sess.prefill(long_prompt)
        outs = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(n):
            logits = sess.decode(tok)
            outs.append(logits)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return jnp.stack(outs)

    dense = ServeSession(cfg, params, max_seq=512, batch=1)
    base = decode_tail(dense)

    print(f"{'setting':>22} | {'cache MB':>9} | {'saving':>7} | "
          f"{'top1 agree':>10} | max|Δlogit| over 12 decodes")
    k_max = cfg.d_head
    for k_active in [16, 12, 8, 4]:
        swan = SwanConfig(k_max=k_max, buffer=32, mode="topk",
                          k_key=k_active, k_value=k_active)
        sess = ServeSession(cfg, absorbed, swan=swan,
                            projections=projections, max_seq=512, batch=1)
        out = decode_tail(sess)
        err = float(jnp.max(jnp.abs(out - base)))
        agree = float((jnp.argmax(out, -1) == jnp.argmax(base, -1)).mean())
        # memory at the *allocation* that k_active would need
        fp = model_cache_footprint(cfg, SwanConfig(k_max=k_active, buffer=32),
                                   1, 384)
        print(f"   k_active={k_active:3d}/{k_max:3d}    | "
              f"{fp.swan_bytes / 1e6:9.3f} | {fp.saving:7.1%} | "
              f"{agree:10.2f} | {err:.4f}")
    print("\nruntime knob: all four sessions share ONE set of weights and")
    print("projections; only the SwanConfig changed (no offline step).")


if __name__ == "__main__":
    main()
