"""Train a small LM end-to-end (a few hundred steps on CPU), checkpoint,
resume, then calibrate + serve it with SWAN.

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]

Exercises: data pipeline -> train loop (grad clip, schedule, async
checkpoints, straggler watchdog) -> resume-from-checkpoint -> SWAN
calibration on the trained weights -> compressed serving quality readout.
"""
import argparse
import os
import shutil
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)   # for benchmarks.common helpers

import jax
import jax.numpy as jnp

from repro.configs import OptimizerConfig, SwanConfig, TrainConfig
from repro.models import get_model
from repro.runtime.serve_loop import ServeSession, calibrate_swan
from repro.runtime.train_loop import Trainer
from benchmarks.common import (swan_teacher_forced_nll, tiny_lm_config,
                               eval_tokens)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_train_tiny")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = tiny_lm_config()
    tc = TrainConfig(model=cfg, seq_len=64, global_batch=16, steps=args.steps,
                     optimizer=OptimizerConfig(lr=6e-3, warmup_steps=20,
                                               decay_steps=args.steps),
                     checkpoint_dir=args.ckpt,
                     checkpoint_every=args.steps // 2, log_every=20)

    # train the first half, "crash", then resume (restart semantics demo)
    t1 = Trainer(tc)
    t1.run(steps=args.steps // 2)
    print(f"-- simulated preemption at step {args.steps // 2}; resuming --")
    t2 = Trainer(tc)
    out = t2.run()
    for row in out["log"][:2] + out["log"][-2:]:
        print(f"  step {row['step']:4d}  loss {row['loss']:.3f}  "
              f"lr {row['lr']:.2e}")
    if out["stragglers"]:
        print(f"  watchdog flagged {len(out['stragglers'])} straggler steps")

    # SWAN on the trained model
    params = out["params"]
    api = get_model(cfg)
    calib = {"tokens": eval_tokens(cfg, batch=8, seq=96, step=50_000)}
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    tokens = eval_tokens(cfg, seq=128)
    base = swan_teacher_forced_nll(cfg, params, tokens, None)
    print(f"\n{'setting':>24} | eval NLL")
    print(f"{'dense baseline':>24} | {base:.4f}")
    for ratio in (0.75, 0.5):
        k = int(cfg.d_head * ratio)
        swan = SwanConfig(k_max=k, buffer=16, mode="topk")
        nll = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj)
        print(f"{f'swan k={k}/{cfg.d_head} bt=16':>24} | {nll:.4f}")


if __name__ == "__main__":
    main()
