"""Quickstart: calibrate SWAN on a model and serve with a compressed cache.

    PYTHONPATH=src python examples/quickstart.py

Walks the full paper pipeline on a CPU-sized model:
  1. build a llama-family model (random init here; swap in your checkpoint),
  2. offline calibration -> joint-SVD projections (paper §4.1),
  3. absorb P_VO into W_V/W_O (lossless, §4.2),
  4. serve with the hybrid winnowed cache at 50% retention (§4.3),
  5. report the memory saving (Eq. 1 applied to the whole model).
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_loop import ServeSession, calibrate_swan


def main():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    print(f"model: {cfg.name}  d_head={cfg.d_head}  kv_heads={cfg.n_kv_heads}")

    params = api.init_params(jax.random.PRNGKey(0), cfg)

    # --- 1. offline calibration (one forward pass over calibration data) ---
    calib = make_batch(cfg, batch=2, seq=32, seed=7)
    projections = calibrate_swan(api, cfg, params, calib)
    print(f"calibrated projections: p_qk {projections['p_qk'].shape}")

    # --- 2. absorb the value-side rotation into the weights (lossless) -----
    absorbed = api.absorb(params, cfg, projections)

    # --- 3. serve with a compressed cache -----------------------------------
    swan = SwanConfig(k_max=cfg.d_head // 2, buffer=8, mode="topk")
    sess = ServeSession(cfg, absorbed, swan=swan, projections=projections,
                        max_seq=128, batch=2)
    prompt = make_batch(cfg, batch=2, seq=16, seed=1)
    out = sess.generate(prompt, n_tokens=16)
    print("generated token ids:", out[0].tolist())

    # --- 4. memory accounting (paper Eq. 1) ---------------------------------
    rep = sess.cache_report()
    print(f"cache: {rep['mode']}  {rep['bytes'] / 1e6:.2f} MB "
          f"(dense would be {rep['dense_bytes'] / 1e6:.2f} MB -> "
          f"{rep['saving']:.0%} saving)")

    # --- 5. sanity: full retention reproduces the dense model exactly ------
    exact = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")
    s_dense = ServeSession(cfg, params, max_seq=128, batch=2)
    s_exact = ServeSession(cfg, absorbed, swan=exact, projections=projections,
                           max_seq=128, batch=2)
    a = s_dense.generate(prompt, 12)
    b = s_exact.generate(prompt, 12)
    assert bool(jnp.all(a == b)), "full-retention SWAN must match dense"
    print("losslessness check (Lemmas A.1/A.2): PASS")


if __name__ == "__main__":
    main()
