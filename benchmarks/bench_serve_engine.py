"""Continuous-batching serve engine under a Poisson arrival trace.

Replays a deterministic Poisson request trace (exponential inter-arrivals,
in engine-step units) through ``repro.runtime.serve_engine.ServeEngine``
with mixed prompt lengths and — on the SWAN run — mixed per-request
compression levels k (the paper's runtime-tunable knob; all levels share
one compiled decode executable).  Reports decode throughput (tokens/sec)
and physical KV-cache bytes (paper Eq. 1) for dense vs SWAN serving of the
same trace.  CPU-runnable in seconds.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

N_REQUESTS = 4
N_SLOTS = 2          # < N_REQUESTS: the queue + backfill path is exercised
GEN_TOKENS = 24
MAX_SEQ = 128
ARRIVAL_RATE = 0.25  # requests per engine step (Poisson)


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _trace(cfg, k_cycle):
    """Deterministic Poisson trace: mixed prompt lengths, cycled k."""
    rng = np.random.default_rng(0)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / ARRIVAL_RATE, N_REQUESTS))).astype(int)
    reqs = []
    for i in range(N_REQUESTS):
        plen = [8, 20, 12, 28][i % 4]
        toks = make_batch(cfg, 1, plen, seed=200 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=GEN_TOKENS, arrival_step=int(arrivals[i]),
            k=k_cycle[i % len(k_cycle)]))
    return reqs


def _bench(tag, engine, reqs):
    t0 = time.perf_counter()
    comps = engine.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in comps)
    assert len(comps) == N_REQUESTS, (tag, len(comps))
    rep = engine.cache_report()
    ks = sorted({str(c.k) for c in comps})
    emit(f"serve_engine_{tag}", dt / n_tok * 1e6,
         f"tok_s={n_tok / dt:.1f};cache_bytes={rep['bytes']};"
         f"reqs={len(comps)};steps={engine.step_count};k_levels={'|'.join(ks)}"
         + (f";saving={rep['saving']:.2f}" if "saving" in rep else ""))


def _run() -> None:
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    dense = ServeEngine(cfg, params, max_seq=MAX_SEQ, n_slots=N_SLOTS)
    _bench("dense", dense, _trace(cfg, [None]))

    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=8, buffer=8, mode="topk")
    eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                      max_seq=MAX_SEQ, n_slots=N_SLOTS)
    # two distinct per-request compression levels in one trace
    _bench("swan_mixed_k", eng, _trace(cfg, [8, 4]))
    gate("mixed_k_one_executable", eng.decode_cache_size in (1, -1),
         "mixed k must not re-jit decode")


def run() -> None:
    with bench_record("serve_engine"):
        _run()


if __name__ == "__main__":
    run()
