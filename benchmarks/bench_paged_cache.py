"""Paged sparse KV cache under the Poisson serve trace: memory follows
live tokens.

Replays the deterministic Poisson request trace (mixed prompt lengths,
mixed per-request SWAN k) through two engines over the SAME requests:

  * slab   — every slot reserves ``max_seq`` sparse rows up front
             (reserved == live at all times, by construction);
  * paged  — slots share a page pool (``repro.core.paged_cache``); pages
             are mapped as winnowed tokens land and reclaimed the step a
             sequence retires.

Sampled per engine step: live cache bytes (pool pages actually mapped).
Checks, not just reports:

  * the paged engine is token-identical to the slab engine;
  * live bytes GROW with generated tokens (monotone while no retirement);
  * peak live bytes stay under the slab layout's resident bytes;
  * retirement reclaims pages (free-list grows; pool drains to zero).

CPU-runnable in seconds; ``--smoke`` shrinks the trace for CI (exercised
on both the JAX floor and current pins — see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate, record_metrics
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

N_SLOTS = 2          # < n_requests: the queue + backfill path is exercised
MAX_SEQ = 128
PAGE_SIZE = 16
ARRIVAL_RATE = 0.25  # requests per engine step (Poisson)


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _trace(cfg, n_requests, gen_tokens):
    """Deterministic Poisson trace: mixed prompt lengths, mixed k."""
    rng = np.random.default_rng(0)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / ARRIVAL_RATE, n_requests))).astype(int)
    reqs = []
    for i in range(n_requests):
        plen = [8, 20, 12, 28][i % 4]
        toks = make_batch(cfg, 1, plen, seed=200 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=gen_tokens, arrival_step=int(arrivals[i]),
            k=[8, 4][i % 2]))
    return reqs


def _drain_sampling(engine, reqs):
    """Run the trace step-by-step, sampling live bytes after each step —
    read off the ``kv_cache_live_bytes`` gauge the engine samples every
    step (same ``_cache_bytes()`` source as ``cache_report()``)."""
    for r in reqs:
        engine.submit(r)
    live_series, retired_at = [], []
    t0 = time.perf_counter()
    while not engine.done:
        n_ret = engine.step()
        live_series.append(int(engine.metrics.value("kv_cache_live_bytes")))
        if n_ret:
            retired_at.append(len(live_series) - 1)
    return time.perf_counter() - t0, live_series, retired_at


def _run(smoke: bool = False) -> None:
    n_requests, gen_tokens = (4, 12) if smoke else (6, 24)
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=8, buffer=8, mode="topk")

    slab = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                       max_seq=MAX_SEQ, n_slots=N_SLOTS)
    want = {c.uid: c.tokens for c in slab.run(_trace(cfg, n_requests,
                                                     gen_tokens))}

    paged = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                        max_seq=MAX_SEQ, n_slots=N_SLOTS,
                        paged=True, page_size=PAGE_SIZE)
    dt, live, retired_at = _drain_sampling(
        paged, _trace(cfg, n_requests, gen_tokens))
    got = {c.uid: c.tokens for c in paged.completions}

    # --- acceptance gates --------------------------------------------------
    gate("token_identity", got == want,
         "paged engine diverged from slab engine")
    rep = paged.cache_report()
    slab_rep = slab.cache_report()
    gate("slab_reserved_eq_live",
         slab_rep["reserved_bytes"] == slab_rep["live_bytes"],
         f"{slab_rep['reserved_bytes']} != {slab_rep['live_bytes']}")
    # the gauge and cache_report() read the same _cache_bytes() source
    gate("gauge_matches_report",
         live[-1] == rep["live_bytes"],
         f"gauge {live[-1]} != report {rep['live_bytes']}")
    peak = max(live)
    gate("peak_under_slab", peak < rep["slab_bytes"],
         f"live bytes {peak} should undercut slab {rep['slab_bytes']}")
    # memory must TRACK tokens: strictly growing while sequences only decode
    first_ret = retired_at[0]
    grow = [b for b in live[:first_ret]]
    gate("live_bytes_grow",
         any(b2 > b1 for b1, b2 in zip(grow, grow[1:])),
         "live bytes never grew with generated tokens")
    # retirement reclaims pages: some later sample dips below the peak...
    gate("retirement_reclaims", min(live[first_ret:]) < peak,
         "no pages reclaimed on retirement")
    # ...and a drained pool holds zero live pages
    gate("pool_drained", rep["live_pages"] == 0, "pages leaked after drain")
    paged.pool.check_consistent()

    n_tok = sum(len(t) for t in got.values())
    emit("paged_cache_poisson", dt / n_tok * 1e6,
         f"tok_s={n_tok / dt:.1f};reqs={len(got)};steps={paged.step_count};"
         f"peak_live_bytes={peak};slab_bytes={rep['slab_bytes']};"
         f"reserved_bytes={rep['reserved_bytes']};"
         f"page_size={PAGE_SIZE};prefill_execs={paged.prefill_cache_size}")
    emit("paged_cache_reclaim", 0.0,
         f"live_series_head={'|'.join(str(b) for b in live[:6])};"
         f"retired_steps={len(retired_at)};final_live_pages=0")
    record_metrics(paged.metrics, "paged")


def run(smoke: bool = False) -> None:
    with bench_record("paged_cache"):
        _run(smoke=smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
