"""Paper Fig. 4 (structural reproduction): long-context error propagation.

Without task suites offline, we measure how SWAN's compression error
accumulates with decode length: top-1 agreement and logit error vs the
dense baseline at increasing positions, buffered vs zero-buffer.

Paper shape: bt>0 stays close to baseline far into the sequence; bt=0
drifts rapidly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import SwanConfig
from repro.models import get_model
from benchmarks.common import emit, eval_tokens, trained_tiny_lm
from benchmarks.common import bench_record

CHECKPOINTS = [32, 64, 128, 224]


def _drift(cfg, params_d, params_s, pj, swan, tokens):
    api = get_model(cfg)
    B, S = tokens.shape
    st_d = api.init_serve_state(cfg, None, B, S + 1)
    st_s = api.init_serve_state(cfg, swan, B, S + 1)
    lg_d, st_d = api.prefill(params_d, cfg, {"tokens": tokens[:, :8]}, st_d)
    lg_s, st_s = api.prefill(params_s, cfg, {"tokens": tokens[:, :8]}, st_s,
                             swan, pj)

    @jax.jit
    def step_d(state, tok, pos):
        return api.decode_step(params_d, cfg, tok, pos, state)

    @jax.jit
    def step_s(state, tok, pos):
        return api.decode_step(params_s, cfg, tok, pos, state, swan, pj)

    out = {}
    agree, n = 0, 0
    lg_d, lg_s = lg_d[:, -1], lg_s[:, -1]
    for t in range(8, S):
        agree += float((jnp.argmax(lg_d, -1) == jnp.argmax(lg_s, -1)).mean())
        n += 1
        if t in CHECKPOINTS:
            err = float(jnp.abs(lg_d - lg_s).max())
            out[t] = (agree / n, err)
        tok = tokens[:, t]
        p = jnp.asarray(t, jnp.int32)
        lg_d, st_d = step_d(st_d, tok, p)
        lg_s, st_s = step_s(st_s, tok, p)
    return out


def _run() -> None:
    cfg, params, pj, absorbed = trained_tiny_lm()
    tokens = eval_tokens(cfg, seq=228)
    k = cfg.d_head // 8   # deep-compression regime where drift is visible
    for name, swan in [("bt8", SwanConfig(k_max=k, buffer=8, mode="topk")),
                       ("bt0", SwanConfig(k_max=k, buffer=0, mode="topk"))]:
        t0 = time.perf_counter()
        drift = _drift(cfg, params, absorbed, pj, swan, tokens)
        us = (time.perf_counter() - t0) * 1e6 / max(len(drift), 1)
        for t, (agree, err) in sorted(drift.items()):
            emit("fig4_longcontext_drift", us,
                 f"{name}_pos={t}_top1agree={agree:.3f}_logit_err={err:.3f}")


def run() -> None:
    with bench_record("longcontext_error"):
        _run()


if __name__ == "__main__":
    run()
