"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (spec format).  Quality
benchmarks score a tiny LM trained in-process on the deterministic
synthetic corpus (cached across modules and runs).

Each module also writes a machine-readable ``BENCH_<name>.json`` artifact
(rows + gate verdicts + metrics snapshots) into ``$REPRO_BENCH_OUT``
(default ``bench_out/``); this harness aggregates whatever artifacts are
present into ``BENCH_SUMMARY.json``.
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_adaptive_k, bench_breakeven,
                        bench_buffer_rescue, bench_fig2a_compression,
                        bench_kernels, bench_longcontext_error,
                        bench_memory_footprint, bench_paged_cache,
                        bench_serve_engine, bench_table1_retention,
                        bench_table2_kv_split, bench_table3_projection,
                        bench_warmup)
from benchmarks.common import bench_out_dir

MODULES = [
    ("fig2a_compression", bench_fig2a_compression),
    ("eq2_breakeven", bench_breakeven),
    ("memory_footprint", bench_memory_footprint),
    ("table1_retention", bench_table1_retention),
    ("table2_kv_split", bench_table2_kv_split),
    ("table3_projection", bench_table3_projection),
    ("fig2b_buffer_rescue", bench_buffer_rescue),
    ("fig4_longcontext", bench_longcontext_error),
    ("adaptive_k", bench_adaptive_k),          # beyond-paper extension
    ("serve_engine", bench_serve_engine),      # continuous batching
    ("paged_cache", bench_paged_cache),        # memory follows live tokens
    ("warmup", bench_warmup),                  # executable-family warmup
    ("kernels", bench_kernels),
]


def aggregate() -> dict:
    """Fold every ``BENCH_*.json`` artifact in the output dir into one
    ``BENCH_SUMMARY.json`` (per-bench ok/rows/gates, total gate tally)."""
    outdir = bench_out_dir()
    benches = {}
    for path in sorted(glob.glob(os.path.join(outdir, "BENCH_*.json"))):
        if os.path.basename(path) == "BENCH_SUMMARY.json":
            continue
        try:
            with open(path) as fh:
                art = json.load(fh)
        except (OSError, ValueError) as e:
            print(f"# skipping unreadable artifact {path}: {e}",
                  file=sys.stderr)
            continue
        benches[art.get("bench", os.path.basename(path))] = {
            "ok": art.get("ok", False),
            "jax_version": art.get("jax_version"),
            "n_rows": len(art.get("rows", [])),
            "gates": {g["name"]: g["passed"] for g in art.get("gates", [])},
        }
    summary = {
        "benches": benches,
        "n_benches": len(benches),
        "n_gates": sum(len(b["gates"]) for b in benches.values()),
        "gates_failed": sorted(
            f"{name}:{g}" for name, b in benches.items()
            for g, passed in b["gates"].items() if not passed),
        "all_ok": all(b["ok"] for b in benches.values()),
    }
    if benches:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "BENCH_SUMMARY.json"), "w") as fh:
            json.dump(summary, fh, indent=2, sort_keys=True)
    return summary


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        t0 = time.monotonic()
        try:
            mod.run()
            print(f"# [{name}] ok in {time.monotonic() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# [{name}] FAILED", file=sys.stderr)
            traceback.print_exc()
    summary = aggregate()
    print(f"# {summary['n_benches']} artifacts, {summary['n_gates']} gates "
          f"({len(summary['gates_failed'])} failed) -> "
          f"{os.path.join(bench_out_dir(), 'BENCH_SUMMARY.json')}",
          file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
