"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (spec format).  Quality
benchmarks score a tiny LM trained in-process on the deterministic
synthetic corpus (cached across modules and runs).
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (bench_adaptive_k, bench_breakeven,
                        bench_buffer_rescue, bench_fig2a_compression,
                        bench_kernels, bench_longcontext_error,
                        bench_memory_footprint, bench_paged_cache,
                        bench_serve_engine, bench_table1_retention,
                        bench_table2_kv_split, bench_table3_projection)

MODULES = [
    ("fig2a_compression", bench_fig2a_compression),
    ("eq2_breakeven", bench_breakeven),
    ("memory_footprint", bench_memory_footprint),
    ("table1_retention", bench_table1_retention),
    ("table2_kv_split", bench_table2_kv_split),
    ("table3_projection", bench_table3_projection),
    ("fig2b_buffer_rescue", bench_buffer_rescue),
    ("fig4_longcontext", bench_longcontext_error),
    ("adaptive_k", bench_adaptive_k),          # beyond-paper extension
    ("serve_engine", bench_serve_engine),      # continuous batching
    ("paged_cache", bench_paged_cache),        # memory follows live tokens
    ("kernels", bench_kernels),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        t0 = time.monotonic()
        try:
            mod.run()
            print(f"# [{name}] ok in {time.monotonic() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# [{name}] FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
