"""Executable-family warmup: cold vs warm TTFT, zero steady-state compiles.

Exercises ``repro.runtime.warmup`` end-to-end on the serve engine:

* **cold vs warm TTFT** — the first request on a cold engine pays JIT
  compilation for the prefill chain plus the decode step; after
  ``ServeEngine.warmup()`` the whole executable family is already
  compiled, so the warm first-request TTFT must come in at <= 0.5x the
  cold one (on CPU the gap is typically orders of magnitude).
* **zero steady-state compiles** — after warmup, a randomized mixed
  workload (mixed prompt lengths, per-request k, greedy and temperature
  lanes) must trigger ZERO new XLA compiles, checked with the process
  -global ``repro.obs.compile_events`` listener (which also sees eager
  one-off ops the jit caches cannot) and a stable ``executable_census()``.
  Gated on both the slab and the paged engine.
* **warmup idempotency** — a second ``warmup()`` call compiles nothing.
* **async fetch identity** — ``async_fetch=True`` (decode token transfer
  overlapped with host scheduling) produces token-for-token identical
  output, identical admission/first-token/finish steps, and identical
  per-kind dispatch counts to the synchronous path.

All prompts are prebuilt with numpy BEFORE any compile-count snapshot —
materialising a prompt via ``make_batch`` traces eager slice ops at raw
prompt lengths, which would pollute the zero-compile gates with compiles
the serve path never issues.

CPU-runnable; ``--smoke`` shrinks the family for CI (exercised on both
JAX pins).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate, record_metrics
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.obs import compile_events
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _workload(cfg, prompt_cap, n_requests, seed=0):
    """Randomized mixed workload; every prompt materialised up front."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.randint(1, prompt_cap + 1))
        toks = [int(t) for t in
                make_batch(cfg, 1, max(plen, 1), seed=300 + i)["tokens"][0]]
        temp = float(rng.choice([0.0, 0.0, 0.7, 1.3]))
        reqs.append(Request(
            uid=f"req{i}", tokens=toks[:plen],
            max_new_tokens=int(rng.randint(2, 5)),
            temperature=temp, seed=int(rng.randint(0, 2**31 - 1)),
            k=[None, 4, 8][int(rng.randint(0, 3))]))
    return reqs


def _clone(reqs):
    return [Request(uid=r.uid, tokens=list(r.tokens),
                    max_new_tokens=r.max_new_tokens,
                    temperature=r.temperature, seed=r.seed, k=r.k)
            for r in reqs]


def _ttft_ms(engine, req):
    """Wall-clock from submit to the first generated token, then drain."""
    engine.submit(req)
    t0 = time.perf_counter()
    while engine.metrics.value("serve_tokens_generated_total") < 1:
        engine.step()
    dt = (time.perf_counter() - t0) * 1e3
    while not engine.done:
        engine.step()
    return dt


def _run(smoke: bool = False) -> None:
    if smoke:
        max_seq, chunk, pslots, prompt_cap, n_reqs = 32, 4, 2, 8, 6
    else:
        max_seq, chunk, pslots, prompt_cap, n_reqs = 64, 8, 2, 16, 8
    n_slots, page_size = 2, 16

    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")

    def engine(**kw):
        return ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                           max_seq=max_seq, n_slots=n_slots,
                           prefill_chunk=chunk, prefill_slots=pslots, **kw)

    reqs = _workload(cfg, prompt_cap, n_reqs)
    ttft_req = Request(uid="ttft", tokens=list(reqs[0].tokens),
                       max_new_tokens=4, k=4)

    # --- cold first-request TTFT (pays prefill-chain + decode JIT) --------
    cold = engine()
    cold_ms = _ttft_ms(cold, Request(uid="ttft", tokens=list(ttft_req.tokens),
                                     max_new_tokens=4, k=4))
    emit("warmup_ttft_cold", cold_ms * 1e3, f"prompt_len={len(ttft_req.tokens)}")

    # --- warmed slab engine ----------------------------------------------
    warm = engine()
    rep = warm.warmup(max_prompt_len=prompt_cap)
    emit("warmup_slab", rep["warmup_ms"] * 1e3,
         f"compiles={rep['compiles']};census={rep['census']['total']};"
         f"items={len(rep['items'])}")
    rep2 = warm.warmup(max_prompt_len=prompt_cap)
    gate("warmup_idempotent_slab", rep2["compiles"] == 0,
         f"second warmup compiled {rep2['compiles']}")

    warm_ms = _ttft_ms(warm, Request(uid="ttft", tokens=list(ttft_req.tokens),
                                     max_new_tokens=4, k=4))
    ratio = warm_ms / cold_ms
    emit("warmup_ttft_warm", warm_ms * 1e3, f"ratio_vs_cold={ratio:.4f}")
    gate("warm_ttft_le_half_cold", ratio <= 0.5,
         f"warm {warm_ms:.1f}ms vs cold {cold_ms:.1f}ms (ratio {ratio:.3f})")

    # --- post-warmup randomized workload: zero new compiles --------------
    census0 = warm.executable_census()
    c0 = compile_events.total()
    t0 = time.perf_counter()
    comps_sync = warm.run(_clone(reqs))
    dt = time.perf_counter() - t0
    dc = compile_events.total() - c0
    census1 = warm.executable_census()
    n_tok = sum(len(c.tokens) for c in comps_sync)
    emit("warmup_steady_state_slab", dt / max(n_tok, 1) * 1e6,
         f"reqs={len(comps_sync)};tokens={n_tok};new_compiles={dc}")
    gate("zero_steady_state_compiles_slab", dc == 0 and census1 == census0,
         f"new_compiles={dc} census_delta="
         f"{census1['total'] - census0['total']}")
    record_metrics(warm.metrics, "slab")

    # --- paged engine: warmup + zero-compile workload ---------------------
    pg = engine(paged=True, page_size=page_size)
    prep = pg.warmup(max_prompt_len=prompt_cap)
    emit("warmup_paged", prep["warmup_ms"] * 1e3,
         f"compiles={prep['compiles']};census={prep['census']['total']}")
    gate("warmup_idempotent_paged",
         pg.warmup(max_prompt_len=prompt_cap)["compiles"] == 0,
         "second paged warmup compiled")
    pcensus0 = pg.executable_census()
    c0 = compile_events.total()
    comps_paged = pg.run(_clone(reqs))
    dc = compile_events.total() - c0
    gate("zero_steady_state_compiles_paged",
         dc == 0 and pg.executable_census() == pcensus0,
         f"new_compiles={dc}")
    assert len(comps_paged) == len(reqs)

    # --- async fetch: token/step/dispatch identity to the sync path -------
    e_sync = engine()
    e_async = engine(async_fetch=True)
    c1 = e_sync.run(_clone(reqs))
    c2 = e_async.run(_clone(reqs))
    t1 = {c.uid: c.tokens for c in c1}
    t2 = {c.uid: c.tokens for c in c2}
    s1 = {c.uid: (c.admitted_step, c.first_token_step, c.finished_step)
          for c in c1}
    s2 = {c.uid: (c.admitted_step, c.first_token_step, c.finished_step)
          for c in c2}
    gate("async_token_identity", t1 == t2 and s1 == s2,
         "async fetch must be token- and step-identical to sync")
    gate("async_dispatch_counts", e_sync.dispatches == e_async.dispatches,
         f"sync={e_sync.dispatches} async={e_async.dispatches}")
    # warmed sync run above is the same workload: async == warmed too
    gate("async_matches_warmed",
         t2 == {c.uid: c.tokens for c in comps_sync},
         "async tokens must match the warmed sync run")


def run(smoke: bool = False) -> None:
    with bench_record("warmup"):
        _run(smoke=smoke)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small executable family for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)
