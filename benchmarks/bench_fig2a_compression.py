"""Paper Fig. 2a: compression-vs-pruning trade-off (exact analytical).

Emits the retention-ratio -> effective-compression curve for 16-bit and
8-bit sparse values, plus the break-even retention thresholds the figure
highlights (0.66 for fp16, ~1.0 for 8-bit).
"""
from __future__ import annotations

import time

from repro.core.analytical import (compression_ratio,
                                   memory_breakeven_retention)
from benchmarks.common import emit
from benchmarks.common import bench_record


def _run() -> None:
    d_head = 128
    t0 = time.perf_counter()
    rows = []
    for pct in range(5, 105, 5):
        k = max(int(d_head * pct / 100), 1)
        rows.append((pct / 100, compression_ratio(k, d_head, False),
                     compression_ratio(k, d_head, True)))
    us = (time.perf_counter() - t0) * 1e6
    be16 = memory_breakeven_retention(d_head)
    be8 = memory_breakeven_retention(d_head, bits8=True)
    emit("fig2a_breakeven_fp16", us, f"retention<{be16:.3f}_saves_memory")
    emit("fig2a_breakeven_int8", us, f"retention<{be8:.3f}_saves_memory")
    for r, c16, c8 in rows:
        emit("fig2a_curve", us / len(rows),
             f"retention={r:.2f}_fp16={c16:.3f}_int8={c8:.3f}")


def run() -> None:
    with bench_record("fig2a_compression"):
        _run()


if __name__ == "__main__":
    run()
