"""Whole-model KV-cache memory accounting across the assigned architectures
(the paper's abstract claim: 50-60% per-token savings at strong quality).

Emits dense vs SWAN cache bytes for the serving shapes, per arch, for the
paper-faithful setting (k=d_h/2, bt=128, fp16) and the 8-bit variant.
"""
from __future__ import annotations

from repro.configs import ARCH_IDS, SHAPES, SwanConfig, get_config
from repro.core.analytical import model_cache_footprint
from repro.models import swan_applicable
from benchmarks.common import emit
from benchmarks.common import bench_record


def _run() -> None:
    shape = SHAPES["decode_32k"]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if not swan_applicable(cfg):
            emit("cache_footprint", 0.0, f"{arch}_swan=inapplicable_O(1)_state")
            continue
        for tag, swan in [
            ("fp16_k50", SwanConfig(k_max=cfg.d_head // 2, buffer=128)),
            ("int8_k50", SwanConfig(k_max=cfg.d_head // 2, buffer=128,
                                    quantize=True)),
        ]:
            fp = model_cache_footprint(cfg, swan, shape.global_batch,
                                       shape.seq_len)
            emit("cache_footprint", 0.0,
                 f"{arch}_{tag}_dense={fp.dense_bytes / 1e9:.1f}GB"
                 f"_swan={fp.swan_bytes / 1e9:.1f}GB_saving={fp.saving:.1%}")


def run() -> None:
    with bench_record("memory_footprint"):
        _run()


if __name__ == "__main__":
    run()
