"""Chunked prefill under Poisson long-prompt arrivals: per-step latency.

A monolithic admission prefills the whole prompt inside one engine step, so
every active decode slot stalls for it — the p99 engine-step latency under
a trace with occasional LONG prompts is set by those admission steps.
Chunked prefill (``prefill_chunk``) spends a bounded token budget per step
(one chunk) and still runs the batched decode, so the worst step is
"one chunk + one decode" instead of "one 200-token prefill + one decode".

Replays the SAME deterministic Poisson trace (short decodes + periodic long
prompts) through a monolithic and a chunked slab engine at full SWAN
retention (winnowing exact — the engines must be token-identical), timing
every ``engine.step()`` after a warmup pass that pre-compiles every
executable shape.  Checks, not just reports:

  * chunked tokens == monolithic tokens (full-k exactness);
  * p99 step latency improves under chunking (the admission stall is gone);
  * the worst chunked step stays under the worst monolithic step;
  * chunked prefill executables stay O(log max_seq) (full chunks share one
    shape, remainder chunks bucket to powers of two).

CPU-runnable in seconds; ``--smoke`` shrinks the trace for CI (exercised on
both the JAX floor and current pins — see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

N_SLOTS = 2
MAX_SEQ = 512
CHUNK = 16
ARRIVAL_RATE = 0.5   # requests per engine step (Poisson)
N_PASSES = 2         # timed passes per engine; best-of damps host noise
P99_MARGIN = 1.15    # required improvement headroom: the real margin is
                     # ~1.5x, the slack absorbs shared-runner noise in CI


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _trace(cfg, n_requests, gen_tokens, long_len, tag="", step0=0):
    """Deterministic Poisson arrivals; every third prompt is LONG.
    ``step0`` offsets arrivals to the engine's CURRENT step count —
    ``arrival_step`` is absolute, so a trace replayed after a warmup pass
    must shift or it degenerates into an all-at-once burst."""
    rng = np.random.default_rng(0)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / ARRIVAL_RATE, n_requests))).astype(int)
    reqs = []
    for i in range(n_requests):
        plen = long_len if i % 3 == 2 else [8, 14][i % 2]
        toks = make_batch(cfg, 1, plen, seed=300 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"{tag}req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=gen_tokens,
            arrival_step=step0 + int(arrivals[i])))
    return reqs


def _timed_steps(engine, reqs):
    """Drain ``reqs`` step by step, timing each engine step (host wall
    clock, device-synchronised via the blocking host fetches every step
    already performs)."""
    for r in reqs:
        engine.submit(r)
    durs = []
    while not engine.done:
        t0 = time.perf_counter()
        engine.step()
        jax.block_until_ready(engine.state)
        durs.append(time.perf_counter() - t0)
    return np.asarray(durs)


def _run(smoke: bool = False) -> None:
    n_requests, gen_tokens, long_len = (6, 10, 320) if smoke else (9, 20, 384)
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")  # exact winnow

    stats = {}
    tokens = {}
    for mode, chunk in [("monolithic", None), ("chunked", CHUNK)]:
        eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                          max_seq=MAX_SEQ, n_slots=N_SLOTS,
                          prefill_chunk=chunk)
        # warmup: same trace -> compiles every prefill/decode shape the
        # timed passes will hit
        eng.run(_trace(cfg, n_requests, gen_tokens, long_len, tag="warm"))
        passes = []
        for n in range(N_PASSES):
            durs = _timed_steps(eng, _trace(cfg, n_requests, gen_tokens,
                                            long_len, tag=f"p{n}-",
                                            step0=eng.step_count))
            passes.append({
                "p50": float(np.percentile(durs, 50)),
                "p99": float(np.percentile(durs, 99)),
                "max": float(durs.max()),
                "steps": len(durs),
            })
        tokens[mode] = {c.uid.split("-", 1)[-1]: c.tokens
                        for c in eng.completions
                        if c.uid.startswith("p0-")}
        stats[mode] = min(passes, key=lambda s: s["p99"])
        stats[mode]["prefill_execs"] = eng.prefill_cache_size

    # --- acceptance gates --------------------------------------------------
    gate("token_identity", tokens["chunked"] == tokens["monolithic"],
         "chunked prefill diverged from monolithic admission")
    mono, chk = stats["monolithic"], stats["chunked"]
    # timing gate with noise headroom (CI shares runners; identity and
    # executable-count gates above/below stay exact)
    gate("p99_improves", chk["p99"] * P99_MARGIN < mono["p99"],
         f"chunked p99 {chk['p99'] * 1e3:.2f} ms did not improve on "
         f"monolithic {mono['p99'] * 1e3:.2f} ms by >= {P99_MARGIN}x")
    if chk["prefill_execs"] != -1:
        bound = 2 * int(math.log2(MAX_SEQ)) + 2
        gate("prefill_execs_log_bound", chk["prefill_execs"] <= bound,
             f"{chk['prefill_execs']} prefill executables > O(log max_seq)")

    for mode, s in stats.items():
        emit(f"chunked_prefill_{mode}", s["p99"] * 1e6,
             f"p50_us={s['p50'] * 1e6:.0f};p99_us={s['p99'] * 1e6:.0f};"
             f"max_us={s['max'] * 1e6:.0f};steps={s['steps']};"
             f"prefill_execs={s['prefill_execs']}")
    emit("chunked_prefill_p99_speedup", mono["p99"] / chk["p99"],
         f"chunk={CHUNK};long_len={long_len};slots={N_SLOTS};"
         f"max_seq={MAX_SEQ}")


def run(smoke: bool = False) -> None:
    with bench_record("chunked_prefill"):
        _run(smoke=smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small trace for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
