"""Paper Fig. 2b / Fig. 3 (structural reproduction): the dense-buffer
rescue.  Quality vs retention for bt=0 vs bt=8 vs bt=8+int8.

Paper shape: zero-buffer variants collapse; buffered variants degrade
gracefully.  Scale note: with d_head=32 (vs the paper's 128) the collapse
region sits at deeper retention ratios (~0.1 vs the paper's ~0.3) — the
sweep below covers the crossover: at k=2 the zero-buffer variant collapses
(NLL ≈ 4.8) while bt=8 holds ≈ 3.3 (see bench_output.txt).
"""
from __future__ import annotations

import time

from repro.configs import SwanConfig
from benchmarks.common import (emit, eval_tokens, swan_teacher_forced_nll,
                               trained_tiny_lm)
from benchmarks.common import bench_record

RATIOS = [0.5, 0.19, 0.09, 0.06]


def _run() -> None:
    cfg, params, pj, absorbed = trained_tiny_lm()
    tokens = eval_tokens(cfg)
    variants = [("bt0_fp", 0, False), ("bt8_fp", 8, False),
                ("bt8_int8", 8, True)]
    for ratio in RATIOS:
        k = max(int(round(cfg.d_head * ratio)), 1)
        for name, bt, q8 in variants:
            swan = SwanConfig(k_max=k, buffer=bt, mode="topk", quantize=q8)
            t0 = time.perf_counter()
            nll = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj)
            emit("fig2b_buffer_rescue", (time.perf_counter() - t0) * 1e6,
                 f"ratio={ratio:.2f}_{name}_nll={nll:.4f}")


def run() -> None:
    with bench_record("buffer_rescue"):
        _run()


if __name__ == "__main__":
    run()
