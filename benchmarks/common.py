"""Shared benchmark infrastructure.

* ``trained_tiny_lm()`` — trains (once, cached in-process and on disk) a
  small llama-family LM on the deterministic synthetic corpus; all quality
  benchmarks (paper Tables 1-3, Figs 2b/3/4 structural reproductions) score
  this model under different SWAN settings.
* ``swan_teacher_forced_nll`` — SWAN-faithful perplexity: tokens are scored
  through the *serving* path (prefill + incremental decode with the
  compressed hybrid cache), so compression errors propagate exactly as in
  deployment.
* ``timeit_call`` — microbenchmark helper emitting us_per_call.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import (ModelConfig, OptimizerConfig, SwanConfig,
                           TrainConfig)
from repro.core import projections as proj_mod
from repro.data.pipeline import SyntheticStream
from repro.models import get_model
from repro.runtime.train_loop import Trainer

CKPT_DIR = os.environ.get("REPRO_BENCH_CKPT", "/tmp/repro_bench_lm")
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "900"))


def tiny_lm_config() -> ModelConfig:
    return ModelConfig(
        name="bench-tiny-lm", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=384, vocab_size=512,
        norm="rmsnorm", act="silu", rope_theta=10000.0,
        dtype="float32", param_dtype="float32", remat=False,
    )


@functools.lru_cache(maxsize=1)
def trained_tiny_lm():
    """Returns (cfg, params, projections, absorbed_params)."""
    cfg = tiny_lm_config()
    tc = TrainConfig(
        model=cfg, seq_len=64, global_batch=16, steps=TRAIN_STEPS,
        optimizer=OptimizerConfig(lr=6e-3, warmup_steps=20,
                                  decay_steps=TRAIN_STEPS),
        checkpoint_dir=CKPT_DIR, checkpoint_every=TRAIN_STEPS,
        log_every=max(TRAIN_STEPS // 5, 1), seed=0)
    trainer = Trainer(tc)
    latest = trainer.ckpt.latest_step()
    if latest is not None and latest >= TRAIN_STEPS:
        params, _, _ = trainer.restore_or_init()
        log = None
    else:
        out = trainer.run()
        params = out["params"]
        log = out["log"]
    api = get_model(cfg)
    # calibration data: SAME synthetic language as training (seed) but an
    # unseen step index — mirrors the paper's held-out calibration set
    calib = {"tokens": jnp.asarray(
        SyntheticStream(cfg.vocab_size, 8, 96, seed=0).batch_at(50_000)["tokens"][:, :96])}
    q, k, v, wo = api.collect_qkv(params, cfg, calib)
    pj = proj_mod.compute_projections((q, k, v), wo, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.d_head)
    absorbed = api.absorb(params, cfg, pj)
    if log:
        print(f"# tiny-lm trained: loss {log[0]['loss']:.3f} -> "
              f"{log[-1]['loss']:.3f} over {TRAIN_STEPS} steps")
    return cfg, params, pj, absorbed


def eval_tokens(cfg, batch: int = 8, seq: int = 160, step: int = 100_000):
    """Held-out batch from the TRAINING language (same seed, unseen step)."""
    s = SyntheticStream(cfg.vocab_size, batch, seq, seed=0)
    return jnp.asarray(s.batch_at(step)["tokens"][:, :seq])


def swan_teacher_forced_nll(cfg, params, tokens, swan: Optional[SwanConfig],
                            projections=None, prompt_len: int = 8) -> float:
    """Mean NLL of tokens[prompt_len:] scored through the serving path."""
    api = get_model(cfg)
    B, S = tokens.shape
    state = api.init_serve_state(cfg, swan, B, S + 1)
    prompt = {"tokens": tokens[:, :prompt_len]}
    logits, state = api.prefill(params, cfg, prompt, state, swan, projections)
    logits = logits[:, -1]

    @jax.jit
    def step(state, tok, pos):
        return api.decode_step(params, cfg, tok, pos, state, swan, projections)

    nll, count = 0.0, 0
    for t in range(prompt_len, S):
        target = tokens[:, t]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll += float(-jnp.take_along_axis(lp, target[:, None], 1).mean())
        count += 1
        if t < S - 1:
            logits, state = step(state, target, jnp.asarray(t, jnp.int32))
    return nll / count


def timeit_call(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """us per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row in the required ``name,us_per_call,derived`` format."""
    print(f"{name},{us_per_call:.1f},{derived}")
