"""Shared benchmark infrastructure.

* ``trained_tiny_lm()`` — trains (once, cached in-process and on disk) a
  small llama-family LM on the deterministic synthetic corpus; all quality
  benchmarks (paper Tables 1-3, Figs 2b/3/4 structural reproductions) score
  this model under different SWAN settings.
* ``swan_teacher_forced_nll`` — SWAN-faithful perplexity: tokens are scored
  through the *serving* path (prefill + incremental decode with the
  compressed hybrid cache), so compression errors propagate exactly as in
  deployment.
* ``timeit_call`` — microbenchmark helper emitting us_per_call.
* ``bench_record`` / ``BenchRecorder`` — machine-readable run artifacts:
  every benchmark writes ``BENCH_<name>.json`` (CSV rows, gate results,
  optional metrics snapshots, jax version) into ``$REPRO_BENCH_OUT``
  (default ``bench_out/``); ``benchmarks/run.py`` aggregates them and CI
  uploads them from both JAX pins.
"""
from __future__ import annotations

import functools
import json
import os
import sys
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import (ModelConfig, OptimizerConfig, SwanConfig,
                           TrainConfig)
from repro.core import projections as proj_mod
from repro.data.pipeline import SyntheticStream
from repro.models import get_model
from repro.runtime.train_loop import Trainer

CKPT_DIR = os.environ.get("REPRO_BENCH_CKPT", "/tmp/repro_bench_lm")
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "900"))


def tiny_lm_config() -> ModelConfig:
    return ModelConfig(
        name="bench-tiny-lm", family="dense",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=384, vocab_size=512,
        norm="rmsnorm", act="silu", rope_theta=10000.0,
        dtype="float32", param_dtype="float32", remat=False,
    )


@functools.lru_cache(maxsize=1)
def trained_tiny_lm():
    """Returns (cfg, params, projections, absorbed_params)."""
    cfg = tiny_lm_config()
    tc = TrainConfig(
        model=cfg, seq_len=64, global_batch=16, steps=TRAIN_STEPS,
        optimizer=OptimizerConfig(lr=6e-3, warmup_steps=20,
                                  decay_steps=TRAIN_STEPS),
        checkpoint_dir=CKPT_DIR, checkpoint_every=TRAIN_STEPS,
        log_every=max(TRAIN_STEPS // 5, 1), seed=0)
    trainer = Trainer(tc)
    latest = trainer.ckpt.latest_step()
    if latest is not None and latest >= TRAIN_STEPS:
        params, _, _ = trainer.restore_or_init()
        log = None
    else:
        out = trainer.run()
        params = out["params"]
        log = out["log"]
    api = get_model(cfg)
    # calibration data: SAME synthetic language as training (seed) but an
    # unseen step index — mirrors the paper's held-out calibration set
    calib = {"tokens": jnp.asarray(
        SyntheticStream(cfg.vocab_size, 8, 96, seed=0).batch_at(50_000)["tokens"][:, :96])}
    q, k, v, wo = api.collect_qkv(params, cfg, calib)
    pj = proj_mod.compute_projections((q, k, v), wo, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.d_head)
    absorbed = api.absorb(params, cfg, pj)
    if log:
        print(f"# tiny-lm trained: loss {log[0]['loss']:.3f} -> "
              f"{log[-1]['loss']:.3f} over {TRAIN_STEPS} steps")
    return cfg, params, pj, absorbed


def eval_tokens(cfg, batch: int = 8, seq: int = 160, step: int = 100_000):
    """Held-out batch from the TRAINING language (same seed, unseen step)."""
    s = SyntheticStream(cfg.vocab_size, batch, seq, seed=0)
    return jnp.asarray(s.batch_at(step)["tokens"][:, :seq])


def swan_teacher_forced_nll(cfg, params, tokens, swan: Optional[SwanConfig],
                            projections=None, prompt_len: int = 8) -> float:
    """Mean NLL of tokens[prompt_len:] scored through the serving path."""
    api = get_model(cfg)
    B, S = tokens.shape
    state = api.init_serve_state(cfg, swan, B, S + 1)
    prompt = {"tokens": tokens[:, :prompt_len]}
    logits, state = api.prefill(params, cfg, prompt, state, swan, projections)
    logits = logits[:, -1]

    @jax.jit
    def step(state, tok, pos):
        return api.decode_step(params, cfg, tok, pos, state, swan, projections)

    nll, count = 0.0, 0
    for t in range(prompt_len, S):
        target = tokens[:, t]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll += float(-jnp.take_along_axis(lp, target[:, None], 1).mean())
        count += 1
        if t < S - 1:
            logits, state = step(state, target, jnp.asarray(t, jnp.int32))
    return nll / count


def timeit_call(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """us per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV row in the required ``name,us_per_call,derived`` format.  Also
    recorded into the active :class:`BenchRecorder`, if any."""
    print(f"{name},{us_per_call:.1f},{derived}")
    if _ACTIVE is not None:
        _ACTIVE.rows.append({"name": name,
                             "us_per_call": float(us_per_call),
                             "derived": derived})


# ---------------------------------------------------------------------------
# Machine-readable benchmark artifacts
# ---------------------------------------------------------------------------

_ACTIVE: Optional["BenchRecorder"] = None


class BenchRecorder:
    """Collects one benchmark's CSV rows, gate verdicts and metrics
    snapshots for the ``BENCH_<name>.json`` artifact."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict[str, Any]] = []
        self.gates: List[Dict[str, Any]] = []
        self.extra: Dict[str, Any] = {}

    def gate(self, name: str, passed: bool, detail: str = "") -> None:
        """Record a pass/fail gate, THEN assert it — a failing gate still
        lands in the JSON artifact (written in ``bench_record``'s finally
        block), so CI uploads show which gate tripped."""
        self.gates.append({"name": name, "passed": bool(passed),
                           "detail": detail})
        assert passed, f"gate {name}: {detail}"

    def add_metrics(self, registry, tag: str = "engine") -> None:
        """Attach a ``repro.obs`` MetricsRegistry snapshot under ``tag``."""
        self.extra.setdefault("metrics", {})[tag] = registry.snapshot()

    def payload(self, ok: bool) -> Dict[str, Any]:
        import jax as _jax
        return {"bench": self.name, "ok": ok, "jax_version": _jax.__version__,
                "rows": self.rows, "gates": self.gates, "extra": self.extra}


def bench_out_dir() -> str:
    return os.environ.get("REPRO_BENCH_OUT", "bench_out")


def gate(name: str, passed: bool, detail: str = "") -> None:
    """Module-level gate: records into the active recorder when one is
    open (so the artifact keeps the verdict), always asserts."""
    if _ACTIVE is not None:
        _ACTIVE.gate(name, passed, detail)
    else:
        assert passed, f"gate {name}: {detail}"


def record_metrics(registry, tag: str = "engine") -> None:
    """Attach a metrics snapshot to the active recorder (no-op outside
    ``bench_record``)."""
    if _ACTIVE is not None:
        _ACTIVE.add_metrics(registry, tag)


@contextmanager
def bench_record(name: str):
    """Scope one benchmark run: ``emit``/``gate`` calls inside are
    captured, and ``BENCH_<name>.json`` is written on exit — also when a
    gate fails, with ``ok: false`` and the failing verdict included."""
    global _ACTIVE
    rec = BenchRecorder(name)
    prev, _ACTIVE = _ACTIVE, rec
    ok = False
    try:
        yield rec
        ok = True
    finally:
        _ACTIVE = prev
        outdir = bench_out_dir()
        try:
            os.makedirs(outdir, exist_ok=True)
            path = os.path.join(outdir, f"BENCH_{name}.json")
            with open(path, "w") as fh:
                json.dump(rec.payload(ok), fh, indent=2, sort_keys=True)
        except OSError as e:                      # never mask the gate error
            print(f"# bench_record({name}): artifact write failed: {e}",
                  file=sys.stderr)
