"""Paper Table 3 (structural reproduction): projection-specificity ablation
at 50% retention — our data-driven joint-SVD basis vs Random / Layer-Shuffle
/ KV-Shuffle / Head-Shuffle variants.

Paper shape: Ours > shuffles > random.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import SwanConfig
from repro.core.projections import random_orthogonal
from repro.models import get_model
from benchmarks.common import (emit, eval_tokens, swan_teacher_forced_nll,
                               trained_tiny_lm)
from benchmarks.common import bench_record


def _variants(cfg, pj, params):
    key = jax.random.PRNGKey(42)
    L, Kv, dh, _ = pj["p_qk"].shape
    yield "ours", pj, None
    rnd = {"p_qk": random_orthogonal(key, (L, Kv), dh),
           "p_vo": random_orthogonal(jax.random.fold_in(key, 1), (L, Kv), dh)}
    yield "random", rnd, None
    perm_l = jax.random.permutation(jax.random.fold_in(key, 2), L)
    yield "layer_shuffle", {"p_qk": pj["p_qk"][perm_l],
                            "p_vo": pj["p_vo"][perm_l]}, None
    yield "kv_swap", {"p_qk": pj["p_vo"], "p_vo": pj["p_qk"]}, None
    perm_h = jax.random.permutation(jax.random.fold_in(key, 3), Kv)
    yield "head_shuffle", {"p_qk": pj["p_qk"][:, perm_h],
                           "p_vo": pj["p_vo"][:, perm_h]}, None


def _run() -> None:
    cfg, params, pj, _ = trained_tiny_lm()
    api = get_model(cfg)
    tokens = eval_tokens(cfg)
    swan = SwanConfig(k_max=cfg.d_head // 2, buffer=0, mode="topk")
    results = {}
    for name, pjv, _ in _variants(cfg, pj, params):
        absorbed_v = api.absorb(params, cfg, pjv)
        t0 = time.perf_counter()
        nll = swan_teacher_forced_nll(cfg, absorbed_v, tokens, swan, pjv)
        results[name] = nll
        emit("table3_projection", (time.perf_counter() - t0) * 1e6,
             f"variant={name}_nll={nll:.4f}")
    ok = results["ours"] <= min(v for k, v in results.items() if k != "ours") + 1e-3
    emit("table3_projection_check", 0.0,
         f"ours_best={'yes' if ok else 'NO'}")


def run() -> None:
    with bench_record("table3_projection"):
        _run()


if __name__ == "__main__":
    run()
