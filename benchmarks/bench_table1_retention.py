"""Paper Table 1 (structural reproduction): quality vs top-k retention
ratio on the trained tiny LM, scored through the SWAN serving path.

Paper shape to reproduce: ~flat through ratio 0.75, mild loss at 0.5,
collapse at 0.3.
"""
from __future__ import annotations

import time

from repro.configs import SwanConfig
from benchmarks.common import (emit, eval_tokens, swan_teacher_forced_nll,
                               trained_tiny_lm)
from benchmarks.common import bench_record

RATIOS = [1.0, 0.9, 0.75, 0.5, 0.3, 0.1]


def _run() -> None:
    cfg, params, pj, absorbed = trained_tiny_lm()
    tokens = eval_tokens(cfg)
    t0 = time.perf_counter()
    base = swan_teacher_forced_nll(cfg, params, tokens, None)
    emit("table1_retention_baseline", (time.perf_counter() - t0) * 1e6,
         f"ratio=1.00_nll={base:.4f}")
    for ratio in RATIOS:
        k = max(int(round(cfg.d_head * ratio)), 1)
        swan = SwanConfig(k_max=k, buffer=8, mode="topk")
        t0 = time.perf_counter()
        nll = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj)
        emit("table1_retention", (time.perf_counter() - t0) * 1e6,
             f"ratio={ratio:.2f}_k={k}_nll={nll:.4f}_delta={nll - base:+.4f}")


def run() -> None:
    with bench_record("table1_retention"):
        _run()


if __name__ == "__main__":
    run()
