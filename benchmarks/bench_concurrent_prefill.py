"""Batched concurrent prefill under a Poisson admission burst: p99 TTFT.

With one in-flight prefill advancing one chunk per engine step, an
admission burst serializes: the Nth queued request's time-to-first-token
grows as O(queue depth × prompt chunks).  The batched concurrent scheduler
(``prefill_slots=P``) round-robins the per-step token budget across up to
P in-flight prefills and packs their chunks into ONE multi-slot executable
— TTFT becomes O(prompt chunks) while each step still issues exactly one
chunk dispatch and one decode dispatch.

Replays the SAME deterministic Poisson burst trace (clustered arrivals,
mixed short/long prompts) through a serial-prefill engine (P=1, the old
one-slot-per-step budget) and a batched-concurrent engine (P=n_slots) at
full SWAN retention.  TTFT is measured in ENGINE STEPS
(``Completion.first_token_step - arrival_step``) — a deterministic
scheduler property, so the gates hold on any shared CI runner:

  * batched tokens == serial tokens (the scheduler never changes outputs);
  * p99 TTFT (steps) of the batched engine <= 0.6x the serial engine;
  * equal decode throughput: the batched engine drains the trace in no
    more engine steps than the serial one (one decode dispatch per step
    in both);
  * the multi-slot executable count stays O(log slots × log chunk ×
    log max_seq) — packing P lanes must not compile per-combination.

Wall-clock per-step latency is reported for color (not gated).
CPU-runnable in seconds; ``--smoke`` shrinks the trace for CI (exercised
on both the JAX floor and current pins — see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

N_SLOTS = 8          # burst fits in slots: TTFT is then pure prefill
                     # scheduling, not slot-turnaround queueing
MAX_SEQ = 512
CHUNK = 16
BURST_RATE = 3.0     # requests per engine step (Poisson) — admission burst
TTFT_GATE = 0.6      # required p99 TTFT ratio: batched <= 0.6 * serial


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _trace(cfg, n_requests, gen_tokens, long_len):
    """Deterministic Poisson burst: clustered arrivals, every third prompt
    LONG — the admission pattern that serializes a one-slot prefill
    budget."""
    rng = np.random.default_rng(0)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / BURST_RATE, n_requests))).astype(int)
    reqs = []
    for i in range(n_requests):
        plen = long_len if i % 3 == 2 else [12, 28][i % 2]
        toks = make_batch(cfg, 1, plen, seed=500 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=gen_tokens, arrival_step=int(arrivals[i])))
    return reqs


def _drain_timed(engine, reqs):
    for r in reqs:
        engine.submit(r)
    durs = []
    while not engine.done:
        t0 = time.perf_counter()
        engine.step()
        jax.block_until_ready(engine.state)
        durs.append(time.perf_counter() - t0)
    return np.asarray(durs)


def _run(smoke: bool = False) -> None:
    n_requests, gen_tokens, long_len = (8, 6, 96) if smoke else (8, 16, 192)
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")

    stats = {}
    tokens = {}
    for mode, p_slots in [("serial", 1), ("batched", N_SLOTS)]:
        eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                          max_seq=MAX_SEQ, n_slots=N_SLOTS,
                          prefill_chunk=CHUNK, prefill_slots=p_slots)
        durs = _drain_timed(eng, _trace(cfg, n_requests, gen_tokens,
                                        long_len))
        by = {c.uid: c for c in eng.completions}
        ttft = np.asarray(
            [by[r.uid].first_token_step - r.arrival_step
             for r in _trace(cfg, n_requests, gen_tokens, long_len)],
            np.float64)
        tokens[mode] = {u: c.tokens for u, c in by.items()}
        stats[mode] = {
            "ttft_p50": float(np.percentile(ttft, 50)),
            "ttft_p99": float(np.percentile(ttft, 99)),
            "ttft_max": float(ttft.max()),
            "engine_steps": eng.step_count,
            "step_p99_us": float(np.percentile(durs, 99) * 1e6),
            "prefill_execs": eng.prefill_cache_size,
        }

    # --- acceptance gates ---------------------------------------------------
    ser, bat = stats["serial"], stats["batched"]
    gate("token_identity", tokens["batched"] == tokens["serial"],
         "batched concurrent prefill diverged from the serial scheduler")
    gate("ttft_p99", bat["ttft_p99"] <= TTFT_GATE * ser["ttft_p99"],
         f"batched p99 TTFT {bat['ttft_p99']:.0f} steps did not reach "
         f"{TTFT_GATE}x serial ({ser['ttft_p99']:.0f} steps)")
    gate("no_extra_steps", bat["engine_steps"] <= ser["engine_steps"],
         "batched scheduler slowed decode drain (more engine steps)")
    if bat["prefill_execs"] != -1:
        bound = (int(math.log2(N_SLOTS)) + 1) * 2 * (int(math.log2(MAX_SEQ)) + 1)
        gate("prefill_execs_bound", bat["prefill_execs"] <= bound,
             f"{bat['prefill_execs']} multi-slot prefill executables > bound")

    for mode, s in stats.items():
        emit(f"concurrent_prefill_{mode}", s["ttft_p99"],
             f"ttft_p50={s['ttft_p50']:.0f};ttft_p99={s['ttft_p99']:.0f};"
             f"ttft_max={s['ttft_max']:.0f};steps={s['engine_steps']};"
             f"step_p99_us={s['step_p99_us']:.0f};"
             f"prefill_execs={s['prefill_execs']}")
    emit("concurrent_prefill_ttft_speedup",
         ser["ttft_p99"] / max(bat["ttft_p99"], 1e-9),
         f"slots={N_SLOTS};chunk={CHUNK};burst_rate={BURST_RATE};"
         f"gate={TTFT_GATE}")


def run(smoke: bool = False) -> None:
    with bench_record("concurrent_prefill"):
        _run(smoke=smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small trace for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
