"""Mesh-sharded serve engine under the Poisson trace: token identity and
dispatch-count independence from shard count.

The sharded engine partitions the batched serve state — slab/ring leaves,
per-sequence pos/k, and the paged pool's page axis — over a ``data`` mesh
axis, with a shard-local slot scheduler on the host (admission, budgeted
round-robin prefill and retirement all decide per shard).  The property
this benchmark gates is the one that makes the design scale: the HOST
issues exactly ONE packed chunk dispatch and ONE decode dispatch per
engine step no matter how many shards the mesh has (the shard fan-out
lives inside shard_map, not in a host loop), and the sharded schedule
never changes a single output token.

Replays the SAME deterministic Poisson trace (mixed prompt lengths, mixed
per-request SWAN k, clustered arrivals, concurrent chunked prefill, paged
pool) through a single-device engine and an 8-way sharded engine on a
simulated host mesh, and gates:

  * sharded tokens == single-device tokens, per request;
  * per-step dispatch count (chunk + decode) identical across shard
    counts, and <= 1 of each per step;
  * the sharded engine drains the trace in the same number of engine
    steps (same decode throughput in scheduler time).

Wall-clock per-step latency is reported for color (not gated — 8 host
devices on one CPU SERIALIZE the per-shard compute; the win is HBM/FLOP
scale-out on real meshes).  ``--smoke`` shrinks the trace for CI
(exercised on both the JAX floor and current pins under
XLA_FLAGS=--xla_force_host_platform_device_count=8 — see
.github/workflows/ci.yml).
"""
from __future__ import annotations

import os

# the mesh must exist before jax initialises — force 8 host devices unless
# the caller (CI) already did
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import time

import jax
import numpy as np

from benchmarks.common import bench_record, emit, gate, record_metrics
from repro.obs import EventTrace
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.launch.mesh import make_serve_mesh
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

DP = 8               # shards on the simulated host mesh
N_SLOTS = 16         # 2 slots per shard
MAX_SEQ = 128
CHUNK = 8
PAGE = 8
BURST_RATE = 2.0     # requests per engine step (Poisson)


def _cfg():
    return get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")


def _trace(cfg, n_requests, gen_tokens):
    """Deterministic Poisson trace: clustered arrivals, mixed prompt
    lengths, mixed per-request k — the full serving feature surface."""
    rng = np.random.default_rng(0)
    arrivals = np.floor(np.cumsum(
        rng.exponential(1.0 / BURST_RATE, n_requests))).astype(int)
    ks = [16, 8, None, 4]
    reqs = []
    for i in range(n_requests):
        plen = [8, 20, 44, 14][i % 4]
        toks = make_batch(cfg, 1, plen, seed=500 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=gen_tokens, k=ks[i % 4],
            arrival_step=int(arrivals[i])))
    return reqs


def _drain(engine, reqs):
    for r in reqs:
        engine.submit(r)
    per_step, durs = [], []
    while not engine.done:
        before = dict(engine.dispatches)
        t0 = time.perf_counter()
        engine.step()
        jax.block_until_ready(engine.state)
        durs.append(time.perf_counter() - t0)
        per_step.append(tuple(engine.dispatches[k] - before[k]
                              for k in ("chunk", "decode")))
    return per_step, np.asarray(durs)


def _run(smoke: bool = False) -> None:
    n_requests, gen_tokens = (10, 5) if smoke else (24, 12)
    cfg = _cfg()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")

    stats, tokens = {}, {}
    for mode, mesh in [("single", None), ("sharded", make_serve_mesh(DP))]:
        eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                          max_seq=MAX_SEQ, n_slots=N_SLOTS, paged=True,
                          page_size=PAGE, prefill_chunk=CHUNK,
                          prefill_slots=4, mesh=mesh)
        per_step, durs = _drain(eng, _trace(cfg, n_requests, gen_tokens))
        tokens[mode] = {c.uid: c.tokens for c in eng.completions}
        stats[mode] = {
            "dp": eng.dp,
            "engine_steps": eng.step_count,
            "chunk_dispatches": eng.dispatches["chunk"],
            "decode_dispatches": eng.dispatches["decode"],
            "max_per_step": max(sum(d) for d in per_step),
            "per_step": per_step,
            "step_p50_us": float(np.percentile(durs, 50) * 1e6),
            "step_p99_us": float(np.percentile(durs, 99) * 1e6),
        }
        assert eng.pool.live_pages == 0
        eng.pool.check_consistent()
        record_metrics(eng.metrics, mode)

    # --- acceptance gates ---------------------------------------------------
    one, sh = stats["single"], stats["sharded"]
    gate("shard_counts", sh["dp"] == DP and one["dp"] == 1,
         f"dp={one['dp']}/{sh['dp']}")
    gate("token_identity", tokens["sharded"] == tokens["single"],
         "sharded engine diverged from the single-device engine")
    # the property that scales: per-step dispatch count is INDEPENDENT of
    # shard count — at most one packed chunk + one decode dispatch per
    # step on ANY mesh (the shard fan-out lives inside shard_map, never in
    # a host loop), so 8 shards never issue more per-step work than 1
    gate("one_dispatch_per_step",
         max(one["max_per_step"], sh["max_per_step"]) <= 2,
         "more than one chunk + one decode dispatch in a step")
    gate("no_per_shard_dispatch",
         all(c <= 1 and d <= 1 for c, d in sh["per_step"]),
         "a sharded step issued per-shard dispatches")
    # per-SHARD prefill budgets mean the sharded engine admits bursts at
    # least as fast — never more total dispatches or steps than 1 device
    gate("no_extra_steps", sh["engine_steps"] <= one["engine_steps"],
         "sharding slowed the drain (more engine steps)")
    gate("no_extra_dispatches",
         sh["chunk_dispatches"] + sh["decode_dispatches"]
         <= one["chunk_dispatches"] + one["decode_dispatches"],
         "sharding increased total dispatch count")

    for mode, s in stats.items():
        emit(f"sharded_serve_{mode}",
             s["chunk_dispatches"] + s["decode_dispatches"],
             f"dp={s['dp']};steps={s['engine_steps']};"
             f"chunk={s['chunk_dispatches']};decode={s['decode_dispatches']};"
             f"max_per_step={s['max_per_step']};"
             f"step_p50_us={s['step_p50_us']:.0f};"
             f"step_p99_us={s['step_p99_us']:.0f}")
    emit("sharded_serve_dispatch_ratio",
         (sh["chunk_dispatches"] + sh["decode_dispatches"])
         / max(one["chunk_dispatches"] + one["decode_dispatches"], 1),
         f"dp={DP};slots={N_SLOTS};chunk={CHUNK};page={PAGE};"
         f"burst_rate={BURST_RATE}")

    # --- instrumentation overhead (observability acceptance: < 3% p99) ----
    # identical single-device drains, compile-warmed, with the full stack
    # OFF (null registry, no trace) vs ON (metrics + in-memory trace);
    # also re-proves token identity and dispatch-count identity on/off
    def _mk(instrumented):
        return ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                           max_seq=MAX_SEQ, n_slots=N_SLOTS, paged=True,
                           page_size=PAGE, prefill_chunk=CHUNK,
                           prefill_slots=4, metrics=instrumented,
                           trace=EventTrace() if instrumented else None)

    p99, toks_oo, disp_oo = {}, {}, {}
    for tag in ("off", "on"):
        eng = _mk(tag == "on")
        _drain(eng, _trace(cfg, n_requests, gen_tokens))     # warm compiles
        best = []
        for _ in range(2):
            _, durs = _drain(eng, _trace(cfg, n_requests, gen_tokens))
            best.append(float(np.percentile(durs, 99)))
        p99[tag] = min(best)
        toks_oo[tag] = {c.uid: c.tokens for c in eng.completions}
        disp_oo[tag] = dict(eng.dispatches)
    gate("obs_token_identity", toks_oo["on"] == toks_oo["off"],
         "metrics/tracing changed output tokens")
    gate("obs_dispatch_identity", disp_oo["on"] == disp_oo["off"],
         f"metrics/tracing changed dispatch counts: "
         f"{disp_oo['off']} vs {disp_oo['on']}")
    # 3% relative + 300us absolute slack (absorbs host-timer noise on the
    # tiny smoke model, where one step is only a few ms)
    budget = p99["off"] * 1.03 + 300e-6
    gate("obs_overhead_p99", p99["on"] <= budget,
         f"instrumented p99 {p99['on'] * 1e6:.0f}us exceeds "
         f"{budget * 1e6:.0f}us (off: {p99['off'] * 1e6:.0f}us)")
    emit("sharded_serve_obs_overhead_p99", p99["on"] * 1e6,
         f"off_p99_us={p99['off'] * 1e6:.0f};"
         f"ratio={p99['on'] / max(p99['off'], 1e-12):.3f}")


def run(smoke: bool = False) -> None:
    with bench_record("sharded_serve"):
        _run(smoke=smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small trace for CI")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
