"""Beyond-paper extension: adaptive per-layer retention (core/adaptive.py).

Same global retention budget, two allocations:
  * uniform  — the paper's single k for every layer,
  * adaptive — water-filled from each layer's calibration spectrum.

Uses the runtime-tunability mechanism (per-layer k_active ≤ k_max), so the
physical allocation is identical — only quality differs.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.configs import SwanConfig
from repro.core.adaptive import allocate_k, spectra_from_joint, uniform_k
from benchmarks.common import (emit, eval_tokens, swan_teacher_forced_nll,
                               trained_tiny_lm)
from benchmarks.common import bench_record


def _run() -> None:
    cfg, params, pj, absorbed = trained_tiny_lm()
    tokens = eval_tokens(cfg)
    spec = spectra_from_joint(pj["spectrum_qk"])
    for avg_k in [8, 4]:
        swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk",
                          k_key=avg_k, k_value=avg_k)
        t0 = time.perf_counter()
        nll_u = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj)
        us = (time.perf_counter() - t0) * 1e6
        k_ad = allocate_k(spec, avg_k, k_min=max(avg_k // 2, 1),
                          k_max=min(2 * avg_k, cfg.d_head))
        pj_ad = dict(pj)
        pj_ad["k_layer"] = jnp.asarray(k_ad)
        t0 = time.perf_counter()
        nll_a = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj_ad)
        us_a = (time.perf_counter() - t0) * 1e6
        emit("adaptive_k", us,
             f"avg_k={avg_k}_uniform_nll={nll_u:.4f}")
        emit("adaptive_k", us_a,
             f"avg_k={avg_k}_adaptive_nll={nll_a:.4f}_alloc={list(k_ad)}"
             f"_delta={nll_a - nll_u:+.4f}")


def run() -> None:
    with bench_record("adaptive_k"):
        _run()


if __name__ == "__main__":
    run()
