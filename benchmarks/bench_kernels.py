"""Kernel microbenchmarks + per-kernel roofline table.

Timing runs wherever the host is (interpret mode on CPU — correctness-path
timing; compiled kernels on TPU).  Each fused kernel additionally gets a
ROOFLINE row: the ideal HBM byte / MXU flop model from
``repro.analysis.roofline`` gives a memory- (or compute-) bound floor
time, and ``achieved_fraction`` = floor / measured.  The fraction is
gated: on TPU the kernels must reach a minimum fraction of the
memory-bound peak; under the CPU interpreter the fraction is a tiny
consistency number and the gate only checks the model produced sane
positive terms.  Rows cover the decode kernel per (k, layout) — the paged
layout per page bucket — and the bulk-chunk prefill kernel, matching the
serve engine's dispatch grid.

CLI: ``python -m benchmarks.bench_kernels [--smoke]`` — smoke shrinks
shapes/iters for CI (both JAX pins run it; ``BENCH_kernels.json`` lands in
``$REPRO_BENCH_OUT`` with the roofline table under ``extra.roofline``).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.configs import SwanConfig, get_smoke_config
from repro.core import hybrid_cache as hc
from repro.core import swan_attention as swa
from repro.core.analytical import sparse_vector_bytes
from repro.kernels.flash_prefill.ops import flash_attention, swan_chunk_stats
from repro.kernels.swan_decode.ops import (swan_decode_attention_kernel,
                                           swan_decode_attention_kernel_paged)
from repro.kernels.swan_prune.ops import swan_prune
from repro.core.projections import random_orthogonal
from benchmarks.common import bench_record, emit, gate, timeit_call

# minimum achieved-fraction-of-peak per backend: on TPU the fused kernels
# are memory-bound streams and must hit a substantial fraction of HBM
# peak; the CPU interpreter executes the kernel body in Python, so the
# gate only requires the model terms to be finite and positive
MIN_FRACTION = {"tpu": 0.4}


def _emit_roofline(rec, row) -> float:
    rec.extra.setdefault("roofline", []).append(row)
    emit(f"roofline_{row['name']}", row["us_per_call"],
         f"bytes={row['hbm_bytes']}_floor_us={row['floor_us']:.2f}"
         f"_frac={row['achieved_fraction']:.2e}_bound={row['bound']}")
    return row["achieved_fraction"]


def _decode_rooflines(rec, cfg, smoke: bool):
    """Decode kernel rows: slab per k, paged per (k, page bucket)."""
    B, bt = 2, 16
    Kv, G, dh = cfg.n_kv_heads, cfg.q_group, cfg.d_head
    S = 128 if smoke else 256
    ps = 32
    ks = (8,) if smoke else (4, 8)
    buckets = (2, 4) if smoke else (4, 8)
    iters, warmup = (2, 1) if smoke else (3, 1)
    key = jax.random.PRNGKey(0)
    fracs = []
    for k in ks:
        swan = SwanConfig(k_max=k, buffer=bt, mode="topk")
        kh = jax.random.normal(key, (B, S - 8, Kv, dh))
        vh = jax.random.normal(jax.random.fold_in(key, 1), (B, S - 8, Kv, dh))
        cache = hc.init_swan_cache(cfg, swan, B, S)
        cache = hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh)
        q = jax.random.normal(jax.random.fold_in(key, 2), (B, Kv, G, dh))
        pos = S - 9
        us = timeit_call(lambda: swan_decode_attention_kernel(
            q, cache, swan, cfg, pos, block_s=64), iters=iters, warmup=warmup)
        nb = rl.swan_decode_kernel_bytes(B=B, Kv=Kv, G=G, dh=dh, S=S,
                                         k_max=k, buffer=bt, quantized=False)
        fracs.append(_emit_roofline(rec, rl.roofline_row(
            f"swan_decode_slab_k{k}", us, nb, kernel="swan_decode",
            layout="slab", k=k)))
        for pb in buckets:
            n_pages = B * pb + 1
            pool_side = {
                "vals": jax.random.normal(jax.random.fold_in(key, 3),
                                          (n_pages, Kv, ps, k)),
                "idx": jax.random.randint(jax.random.fold_in(key, 4),
                                          (n_pages, Kv, ps, k), 0, dh,
                                          jnp.int8),
            }
            pcache = {
                "pool": {"k": pool_side, "v": dict(pool_side)},
                "buf_k": jax.random.normal(jax.random.fold_in(key, 5),
                                           (B, Kv, bt, dh)),
                "buf_v": jax.random.normal(jax.random.fold_in(key, 6),
                                           (B, Kv, bt, dh)),
                "buf_pos": (pb * ps
                            + jnp.arange(bt, dtype=jnp.int32)[None, :]
                            ).repeat(B, 0),
            }
            tab = (1 + jnp.arange(B * pb, dtype=jnp.int32)).reshape(B, pb)
            ppos = jnp.full((B,), pb * ps + bt - 1, jnp.int32)
            us = timeit_call(lambda: swan_decode_attention_kernel_paged(
                q, pcache, swan, cfg, ppos, tab), iters=iters, warmup=warmup)
            nb = rl.swan_decode_kernel_bytes(B=B, Kv=Kv, G=G, dh=dh,
                                             S=pb * ps, k_max=k, buffer=bt,
                                             quantized=False)
            fracs.append(_emit_roofline(rec, rl.roofline_row(
                f"swan_decode_paged_k{k}_pg{pb}", us, nb,
                kernel="swan_decode_paged", layout="paged", k=k,
                page_bucket=pb, page_size=ps)))
    return fracs


def _chunk_roofline(rec, cfg, smoke: bool):
    """Bulk-chunk prefill stats kernel row (the serve chunk dispatch)."""
    B, Q, k = 2, 8, 8
    Kv, dh = cfg.n_kv_heads, cfg.d_head
    S = 64 if smoke else 128
    iters, warmup = (2, 1) if smoke else (3, 1)
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (B, Kv, Q, dh))
    kv = jax.random.normal(jax.random.fold_in(key, 1), (B, Kv, S, k))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, Kv, S, k))
    ki = jax.random.randint(jax.random.fold_in(key, 3), (B, Kv, S, k),
                            0, dh, jnp.int8)
    sp = jnp.full((B,), S, jnp.int32)
    us = timeit_call(lambda: swan_chunk_stats(q, kv, ki, vv, ki, sp,
                                              block_s=32),
                     iters=iters, warmup=warmup)
    nb = rl.swan_chunk_kernel_bytes(B=B, Kv=Kv, Q=Q, dh=dh, S=S, k_max=k,
                                    quantized=False)
    return [_emit_roofline(rec, rl.roofline_row(
        f"swan_chunk_stats_S{S}_k{k}", us, nb, kernel="swan_chunk_stats",
        layout="slab", k=k))]


def _flash_roofline(rec, smoke: bool):
    Sq = 128 if smoke else 256
    iters, warmup = (2, 1) if smoke else (3, 1)
    key = jax.random.PRNGKey(9)
    qf = jax.random.normal(key, (1, Sq, 4, 32), jnp.float32)
    kf = jax.random.normal(key, (1, Sq, 2, 32), jnp.float32)
    us = timeit_call(lambda: flash_attention(qf, kf, kf, block_q=64,
                                             block_k=64),
                     iters=iters, warmup=warmup)
    nb = rl.flash_kernel_bytes(B=1, H=4, Sq=Sq, Sk=Sq, dh=32)
    fl = rl.flash_kernel_flops(B=1, H=4, Sq=Sq, Sk=Sq, dh=32)
    return [_emit_roofline(rec, rl.roofline_row(
        f"flash_prefill_Sq{Sq}", us, nb, flops=fl, kernel="flash_prefill",
        layout="dense"))]


def _legacy_paths(cfg, smoke: bool) -> None:
    """The original XLA-vs-interpret comparison rows (kept: they track the
    pure-JAX reference paths the kernels replace)."""
    B, S, b, k = 2, 128 if smoke else 256, 16, 8
    swan = SwanConfig(k_max=k, buffer=b, mode="topk")
    key = jax.random.PRNGKey(0)
    kh = jax.random.normal(key, (B, S - 56, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, S - 56, cfg.n_kv_heads, cfg.d_head))
    cache = hc.init_swan_cache(cfg, swan, B, S)
    cache = hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh)
    q = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    pos = S - 57
    core = jax.jit(lambda q, c: swa.swan_decode_attention(q, c, swan, cfg,
                                                          pos))
    us = timeit_call(core, q, cache)
    sparse_b = 2 * B * cfg.n_kv_heads * S * sparse_vector_bytes(k)
    dense_b = 2 * B * cfg.n_kv_heads * S * cfg.d_head * 2
    emit("swan_decode_xla_ref", us,
         f"S={S}_k={k}_tpu_bytes={sparse_b}_vs_dense={dense_b}")

    from repro.models.attention import blocked_attention
    Sq = 128 if smoke else 256
    qf = jax.random.normal(key, (1, Sq, 4, 32), jnp.float32)
    kf = jax.random.normal(key, (1, Sq, 2, 32), jnp.float32)
    blk = jax.jit(lambda q, k_, v_: blocked_attention(q, k_, v_, causal=True,
                                                      block=64))
    us = timeit_call(blk, qf, kf, kf)
    flops = 4 * Sq * Sq * 32 * 4
    emit("flash_prefill_xla_blocked", us, f"Sq=Sk={Sq}_flops={flops}")

    x = jax.random.normal(key, (2, 2, 128, 32), jnp.float32)
    P = random_orthogonal(jax.random.fold_in(key, 5), (2,), 32)
    us = timeit_call(lambda: swan_prune(x, P, 8, tile=64), iters=3, warmup=1)
    emit("swan_prune_pallas_interpret", us, "T=128_dh=32_k=8")

    from repro.core.winnow import topk_pack, rotate_k
    prune_ref = jax.jit(
        lambda x, P: topk_pack(rotate_k(x.transpose(0, 2, 1, 3),
                                        P).transpose(0, 2, 1, 3), 8))
    us = timeit_call(prune_ref, x, P)
    emit("swan_prune_xla_ref", us, "T=128_dh=32_k=8")


def _run(rec, smoke: bool) -> None:
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    fracs = []
    fracs += _decode_rooflines(rec, cfg, smoke)
    fracs += _chunk_roofline(rec, cfg, smoke)
    fracs += _flash_roofline(rec, smoke)
    backend = jax.default_backend()
    floor = MIN_FRACTION.get(backend, 0.0)
    worst = min(fracs)
    gate("kernels_roofline_fraction", worst > floor,
         f"backend={backend}: worst achieved fraction {worst:.3e} must "
         f"exceed {floor} over {len(fracs)} kernel rows")
    _legacy_paths(cfg, smoke)


def run(smoke: bool = False) -> None:
    with bench_record("kernels") as rec:
        rec.extra["smoke"] = smoke
        _run(rec, smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters for CI")
    run(smoke=ap.parse_args().smoke)
