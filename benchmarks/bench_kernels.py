"""Kernel microbenchmarks (interpret mode on CPU — correctness-path timing;
TPU wall-clock comes from the roofline model in EXPERIMENTS.md).

Also times the pure-JAX serving paths (the numbers that matter on this
host) and derives the per-call HBM bytes each variant would move on TPU —
the quantity the SWAN kernel actually optimises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SwanConfig, get_smoke_config
from repro.core import hybrid_cache as hc
from repro.core import swan_attention as swa
from repro.core.analytical import sparse_vector_bytes
from repro.kernels.flash_prefill.ops import flash_attention
from repro.kernels.swan_decode.ops import swan_decode_attention_kernel
from repro.kernels.swan_prune.ops import swan_prune
from repro.core.projections import random_orthogonal
from benchmarks.common import emit, timeit_call
from benchmarks.common import bench_record


def _run() -> None:
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    B, S, b, k = 2, 256, 16, 8
    swan = SwanConfig(k_max=k, buffer=b, mode="topk")
    key = jax.random.PRNGKey(0)
    kh = jax.random.normal(key, (B, 200, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, 200, cfg.n_kv_heads, cfg.d_head))
    cache = hc.init_swan_cache(cfg, swan, B, S)
    cache = hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh)
    q = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, cfg.n_kv_heads, cfg.q_group, cfg.d_head))

    # --- decode paths -------------------------------------------------------
    core = jax.jit(lambda q, c: swa.swan_decode_attention(q, c, swan, cfg, 199))
    us = timeit_call(core, q, cache)
    sparse_b = 2 * B * cfg.n_kv_heads * S * sparse_vector_bytes(k)
    dense_b = 2 * B * cfg.n_kv_heads * S * cfg.d_head * 2
    emit("swan_decode_xla_ref", us,
         f"S={S}_k={k}_tpu_bytes={sparse_b}_vs_dense={dense_b}")

    us = timeit_call(lambda: swan_decode_attention_kernel(
        q, cache, swan, cfg, 199, block_s=64), iters=3, warmup=1)
    emit("swan_decode_pallas_interpret", us,
         f"S={S}_k={k}_streams_compressed_cache_once")

    # --- prefill kernel ------------------------------------------------------
    qf = jax.random.normal(key, (1, 256, 4, 32), jnp.float32)
    kf = jax.random.normal(key, (1, 256, 2, 32), jnp.float32)
    vf = jax.random.normal(key, (1, 256, 2, 32), jnp.float32)
    us = timeit_call(lambda: flash_attention(qf, kf, vf, block_q=64,
                                             block_k=64), iters=3, warmup=1)
    flops = 4 * 256 * 256 * 32 * 4
    emit("flash_prefill_pallas_interpret", us, f"Sq=Sk=256_flops={flops}")

    from repro.models.attention import blocked_attention
    blk = jax.jit(lambda q, k_, v_: blocked_attention(q, k_, v_, causal=True,
                                                      block=64))
    us = timeit_call(blk, qf, kf, vf)
    emit("flash_prefill_xla_blocked", us, f"Sq=Sk=256_flops={flops}")

    # --- prune kernel ---------------------------------------------------------
    x = jax.random.normal(key, (2, 2, 128, 32), jnp.float32)
    P = random_orthogonal(jax.random.fold_in(key, 5), (2,), 32)
    us = timeit_call(lambda: swan_prune(x, P, 8, tile=64), iters=3, warmup=1)
    emit("swan_prune_pallas_interpret", us, "T=128_dh=32_k=8")

    from repro.core.winnow import topk_pack, rotate_k
    prune_ref = jax.jit(lambda x, P: topk_pack(rotate_k(x.transpose(0, 2, 1, 3),
                                                        P).transpose(0, 2, 1, 3), 8))
    us = timeit_call(prune_ref, x, P)
    emit("swan_prune_xla_ref", us, "T=128_dh=32_k=8")


def run() -> None:
    with bench_record("kernels"):
        _run()


if __name__ == "__main__":
    run()
