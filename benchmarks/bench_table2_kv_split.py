"""Paper Table 2 (structural reproduction): asymmetric key/value retention
under a fixed budget (TopK_R + TopV_R = 1), zero buffer.

Paper shape: symmetric 0.5/0.5 best; extreme asymmetry catastrophic.
"""
from __future__ import annotations

import time

from repro.configs import SwanConfig
from benchmarks.common import (emit, eval_tokens, swan_teacher_forced_nll,
                               trained_tiny_lm)
from benchmarks.common import bench_record

SPLITS = [(0.2, 0.8), (0.35, 0.65), (0.5, 0.5), (0.65, 0.35), (0.8, 0.2)]


def _run() -> None:
    cfg, params, pj, absorbed = trained_tiny_lm()
    tokens = eval_tokens(cfg)
    for kr, vr in SPLITS:
        kk = max(int(round(cfg.d_head * kr)), 1)
        kv = max(int(round(cfg.d_head * vr)), 1)
        swan = SwanConfig(k_max=max(kk, kv), buffer=0, mode="topk",
                          k_key=kk, k_value=kv)
        t0 = time.perf_counter()
        nll = swan_teacher_forced_nll(cfg, absorbed, tokens, swan, pj)
        emit("table2_kv_split", (time.perf_counter() - t0) * 1e6,
             f"topk_r={kr:.2f}_topv_r={vr:.2f}_nll={nll:.4f}")


def run() -> None:
    with bench_record("table2_kv_split"):
        _run()


if __name__ == "__main__":
    run()
