"""Paper Eq. 2 / Appendix A.2: computational break-even point.

Validates the analytical model against *counted* FLOPs of the reference
implementations (attention-only, per head) and emits the paper's numeric
examples (L = 171/256/512 at b=0; +b with buffer).
"""
from __future__ import annotations

import time

from repro.core.analytical import (breakeven_length, flops_standard,
                                   flops_swan)
from benchmarks.common import emit
from benchmarks.common import bench_record


def _crossing(dh, k, b, lo=1, hi=1 << 20):
    """First L where the counted models cross (binary search)."""
    while lo < hi:
        mid = (lo + hi) // 2
        if flops_swan(mid, dh, k, b) < flops_standard(mid, dh):
            hi = mid
        else:
            lo = mid + 1
    return lo


def _run() -> None:
    dh = 128
    for b in (0, 128):
        for k in (32, 64, 96):
            t0 = time.perf_counter()
            analytic = breakeven_length(dh, k, b)
            counted = _crossing(dh, k, b)
            us = (time.perf_counter() - t0) * 1e6
            ok = abs(counted - analytic) <= 2
            emit("eq2_breakeven", us,
                 f"dh={dh}_k={k}_b={b}_analytic={analytic:.1f}"
                 f"_counted={counted}_match={'yes' if ok else 'NO'}")
    # savings at long context (the paper's motivating regime)
    L = 32_768
    for k in (32, 64):
        ratio = flops_swan(L, dh, k, 128) / flops_standard(L, dh)
        emit("eq2_longctx_flop_ratio", 0.0, f"L=32768_k={k}_swan/std={ratio:.3f}")


def run() -> None:
    with bench_record("breakeven"):
        _run()


if __name__ == "__main__":
    run()
