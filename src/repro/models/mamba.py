"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Diagonal selective SSM:  h_t = exp(Δ_t·A) h_{t-1} + Δ_t·B_t x_t ;
y_t = C_t·h_t + D·x_t, with input-dependent Δ, B, C.

Training uses the *chunked* formulation: because A is diagonal, cumulative
transition products are ``exp(A · cumsum(Δ))``, so each chunk computes an
attention-like intra-chunk term plus a carried inter-chunk state — a
``lax.scan`` over chunks with O(chunk²) intra work and O(1) state, instead
of a token-level scan (compiles small, parallelises over channels; channels
shard over 'model' since the recurrence is channel-diagonal).

Decode keeps the recurrent state explicitly: O(1) memory per step (this is
why SWAN is inapplicable to the mamba layers — nothing grows with context).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.sharding.api import shard

Params = Dict[str, Any]

CHUNK = 128


def _dt_rank(cfg) -> int:
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def init_mamba_params(key, cfg) -> Params:
    m = cfg.mamba
    d, d_in = cfg.d_model, m.expand * cfg.d_model
    R, N = _dt_rank(cfg), m.d_state
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    # S4D-real initialisation for A
    a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (d_in, N))
    dt_init = jnp.exp(jax.random.uniform(ks[4], (d_in,), jnp.float32) *
                      (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    inv_softplus = lambda x: jnp.log(jnp.expm1(x))
    return {
        "w_in":   dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": jax.random.normal(ks[1], (m.d_conv, d_in), jnp.float32).astype(dtype) * (m.d_conv ** -0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_x":    dense_init(ks[2], d_in, R + 2 * N, dtype),
        "w_dt":   dense_init(ks[3], R, d_in, dtype, scale=R ** -0.5),
        "dt_bias": inv_softplus(dt_init).astype(jnp.float32),
        "a_log":  jnp.log(a),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out":  dense_init(ks[5], d_in, d, dtype, scale=d_in ** -0.5),
    }


def _ssm_inputs(p: Params, cfg, u: jnp.ndarray):
    """u [B,S,d_in] (post-conv, post-silu) -> (dt [B,S,d_in], B/C [B,S,N])."""
    N = cfg.mamba.d_state
    R = _dt_rank(cfg)
    xdbc = u @ p["w_x"]
    dt_r, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["w_dt"] + p["dt_bias"].astype(xdbc.dtype))
    return dt.astype(jnp.float32), Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _chunk_scan(dt, A, Bm, Cm, u, h0):
    """One chunk of the diagonal SSM, parallel over time within the chunk.

    dt,u: [B,Q,D]; Bm,Cm: [B,Q,N]; A: [D,N]; h0: [B,D,N].
    Returns (y [B,Q,D], h_out [B,D,N]).
    """
    # cumulative log-decay from chunk start to t (inclusive)
    s = jnp.cumsum(dt, axis=1)                             # [B,Q,D]
    dA = s[..., None] * A[None, None]                      # [B,Q,D,N] (A<0)
    x_in = (dt * u)[..., None] * Bm[:, :, None, :]         # [B,Q,D,N]
    # normalised inputs: w_t = x_t * exp(-A s_t); prefix sums give
    # h_t = exp(A s_t)(h0 + Σ_{τ<=t} w_τ).  exp(-A s) can overflow, so use
    # the stable pairwise form: contribution exp(A (s_t - s_τ)) ∈ (0,1].
    Q = dt.shape[1]
    # intra-chunk: y_t += Σ_τ<=t C_t·exp(A(s_t-s_τ))·(Δu B)_τ   (per channel)
    rel = s[:, :, None, :, None] - s[:, None, :, :, None]  # [B,Q(t),Q(τ),D,1]
    decay = jnp.exp(rel * A[None, None, None])             # [B,Q,Q,D,N]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, :, :, None, None], decay, 0.0)
    cb = Cm[:, :, None, None, :] * Bm[:, None, :, None, :]  # [B,Q,Q,1,N]
    kernel = (decay * cb).sum(-1)                           # [B,Q,Q,D]
    y_intra = jnp.einsum("btsd,bsd->btd", kernel, dt * u)
    # inter-chunk: h0 contribution
    y_h0 = jnp.einsum("btdn,bdn->btd", jnp.exp(dA) * Cm[:, :, None, :], h0)
    # carried state
    w = x_in * jnp.exp(-dA + dA[:, -1:, :, :])              # exp(A(s_Q - s_τ)) stable
    h_out = h0 * jnp.exp(dA[:, -1]) + w.sum(axis=1)
    return y_intra + y_h0, h_out


def mamba_forward(p: Params, cfg, x: jnp.ndarray,
                  chunk: int = CHUNK) -> jnp.ndarray:
    """Training / prefill forward.  x: [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    m = cfg.mamba
    d_in = m.expand * d
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)
    u = shard(u, "mamba_inner")
    # causal depthwise conv
    upad = jnp.pad(u, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    u = sum(upad[:, i:i + S] * p["conv_w"][i][None, None]
            for i in range(m.d_conv)) + p["conv_b"]
    u = jax.nn.silu(u)
    dt, Bm, Cm = _ssm_inputs(p, cfg, u)
    A = -jnp.exp(p["a_log"])
    uf = u.astype(jnp.float32)

    nb = -(-S // chunk)
    pad = nb * chunk - S
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        uf = jnp.pad(uf, ((0, 0), (0, pad), (0, 0)))
    resh = lambda t: t.reshape(B, nb, chunk, -1).transpose(1, 0, 2, 3)

    def step(h, inp):
        dt_c, B_c, C_c, u_c = inp
        y, h = _chunk_scan(dt_c, A, B_c, C_c, u_c, h)
        return h, y

    h0 = jnp.zeros((B, d_in, m.d_state), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (resh(dt), resh(Bm), resh(Cm), resh(uf)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nb * chunk, d_in)[:, :S]
    y = y + uf[:, :S] * p["d_skip"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"]


# ---------------------------------------------------------------------------
# Decode (recurrent state)
# ---------------------------------------------------------------------------

def init_mamba_state(cfg, batch: int) -> Params:
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, m.d_state), jnp.float32),
        "conv": jnp.zeros((batch, m.d_conv - 1, d_in), jnp.dtype(cfg.dtype)),
    }


def mamba_decode_step(p: Params, cfg, x: jnp.ndarray,
                      state: Params) -> Tuple[jnp.ndarray, Params]:
    """x: [B,1,d] -> ([B,1,d], state)."""
    B = x.shape[0]
    m = cfg.mamba
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                       # [B,1,d_in]
    win = jnp.concatenate([state["conv"], u], axis=1)      # [B,d_conv,d_in]
    new_conv = win[:, 1:]
    u1 = (win * p["conv_w"][None]).sum(axis=1) + p["conv_b"]
    u1 = jax.nn.silu(u1)[:, None]                          # [B,1,d_in]
    dt, Bm, Cm = _ssm_inputs(p, cfg, u1)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A[None])              # [B,d_in,N]
    h = state["h"] * dA + (dt[:, 0] * u1[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + u1[:, 0].astype(jnp.float32) * p["d_skip"]
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], {"h": h, "conv": new_conv}


def mamba_reference(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Token-level sequential oracle (tests)."""
    B, S, d = x.shape
    state = init_mamba_state(cfg, B)
    ys = []
    for t in range(S):
        y, state = mamba_decode_step(p, cfg, x[:, t:t + 1], state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
