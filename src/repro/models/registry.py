"""Uniform model API across the four family implementations.

Every family exposes:
  init_params / abstract_params / forward / loss /
  init_serve_state / prefill / decode_step /
  collect_qkv / absorb (None when SWAN is inapplicable — rwkv6)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, jamba, rwkv_model
from repro.models import transformer as tfm

Params = Dict[str, Any]


@dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    forward: Callable                 # (p, cfg, batch) -> (logits, aux)
    init_serve_state: Callable        # (cfg, swan, batch, max_seq) -> state
    prefill: Callable                 # (p, cfg, batch, state, swan, proj) -> (logits, state)
    decode_step: Callable             # (p, cfg, token, pos, state, swan, proj) -> (logits, state)
    collect_qkv: Optional[Callable]   # calibration capture
    absorb: Optional[Callable]
    # (cfg, swan, batch, max_seq, n_pages, page_size) -> paged state; None
    # when the family has no paged sparse layout (recurrent/encdec state)
    init_paged_state: Optional[Callable] = None
    # (p, cfg, batch, state, slot [P], start [P], ...) -> (logits [P, V],
    # state): advance up to P slots' prefills by one chunk each against the
    # BATCHED serve state in ONE executable (batched concurrent prefill;
    # dead lanes park slot out of range); None when the family cannot
    # resume a prefill mid-prompt (recurrent state)
    prefill_chunk: Optional[Callable] = None

    def abstract_params(self, cfg):
        return jax.eval_shape(lambda: self.init_params(jax.random.PRNGKey(0), cfg))

    def loss(self, p, cfg, batch):
        logits, aux = self.forward(p, cfg, batch)
        return _xent_loss(logits, aux, cfg, batch)


def _xent_loss(logits, aux, cfg, batch):
    tokens = batch["tokens"]
    n_prefix = logits.shape[1] - tokens.shape[1]
    if n_prefix > 0:
        logits = logits[:, n_prefix:]
    lg = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    zloss = 1e-4 * (logz ** 2).mean()
    loss = nll + zloss + aux
    return loss, {"nll": nll, "aux": aux, "z": zloss}


# ---------------------------------------------------------------------------
# Family adapters (normalise batch handling)
# ---------------------------------------------------------------------------

def _tfm_forward(p, cfg, batch):
    return tfm.lm_forward(p, cfg, batch["tokens"], batch.get("prefix_embeds"))


def _tfm_prefill(p, cfg, batch, state, swan=None, proj=None, k_active=None,
                 true_len=None):
    return tfm.lm_prefill(p, cfg, batch["tokens"], state, swan, proj,
                          batch.get("prefix_embeds"), k_active=k_active,
                          true_len=true_len)


def _tfm_prefill_chunk(p, cfg, batch, state, slot, start, swan=None,
                       proj=None, k_active=None, true_len=None,
                       page_tab=None, prefix_len=None, use_pallas=False,
                       pallas_interpret=None):
    return tfm.lm_prefill_chunk_batched(p, cfg, batch["tokens"], state, slot,
                                        start, swan, proj, k_active=k_active,
                                        true_len=true_len, page_tab=page_tab,
                                        prefix_len=prefix_len,
                                        use_pallas=use_pallas,
                                        pallas_interpret=pallas_interpret)


def _jamba_forward(p, cfg, batch):
    return jamba.lm_forward(p, cfg, batch["tokens"])


def _jamba_prefill(p, cfg, batch, state, swan=None, proj=None):
    return jamba.prefill(p, cfg, batch["tokens"], state, swan, proj)


def _rwkv_forward(p, cfg, batch):
    return rwkv_model.lm_forward(p, cfg, batch["tokens"])


def _rwkv_prefill(p, cfg, batch, state, swan=None, proj=None):
    return rwkv_model.prefill(p, cfg, batch["tokens"], state, swan, proj)


def _encdec_forward(p, cfg, batch):
    return encdec.lm_forward(p, cfg, batch["tokens"], batch["frames"])


def _encdec_prefill(p, cfg, batch, state, swan=None, proj=None):
    return encdec.prefill(p, cfg, batch["tokens"], state, swan, proj,
                          frames=batch["frames"])


def _encdec_collect(p, cfg, batch):
    return encdec.collect_qkv(p, cfg, batch["tokens"], batch["frames"])


def _tfm_collect(p, cfg, batch):
    return tfm.collect_qkv(p, cfg, batch["tokens"], batch.get("prefix_embeds"))


def _jamba_collect(p, cfg, batch):
    return jamba.collect_qkv(p, cfg, batch["tokens"])


_FAMILIES = {
    "dense": ModelApi(tfm.init_lm_params, _tfm_forward, tfm.init_caches,
                      _tfm_prefill, tfm.lm_decode_step, _tfm_collect,
                      tfm.absorb_swan, tfm.init_paged_caches,
                      _tfm_prefill_chunk),
    "moe":   ModelApi(tfm.init_lm_params, _tfm_forward, tfm.init_caches,
                      _tfm_prefill, tfm.lm_decode_step, _tfm_collect,
                      tfm.absorb_swan, tfm.init_paged_caches,
                      _tfm_prefill_chunk),
    "vlm":   ModelApi(tfm.init_lm_params, _tfm_forward, tfm.init_caches,
                      _tfm_prefill, tfm.lm_decode_step, _tfm_collect,
                      tfm.absorb_swan, tfm.init_paged_caches,
                      _tfm_prefill_chunk),
    "hybrid": ModelApi(jamba.init_lm_params, _jamba_forward,
                       jamba.init_serve_state, _jamba_prefill,
                       jamba.decode_step, _jamba_collect, jamba.absorb_swan),
    "ssm":   ModelApi(rwkv_model.init_lm_params, _rwkv_forward,
                      rwkv_model.init_serve_state, _rwkv_prefill,
                      rwkv_model.decode_step, None, None),
    "encdec": ModelApi(encdec.init_lm_params, _encdec_forward,
                       encdec.init_serve_state, _encdec_prefill,
                       encdec.decode_step, _encdec_collect,
                       encdec.absorb_swan),
}


def get_model(cfg) -> ModelApi:
    return _FAMILIES[cfg.family]


def swan_applicable(cfg) -> bool:
    return get_model(cfg).collect_qkv is not None
