"""Dense feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models.common import act_fn, dense_init, split_keys

Params = Dict[str, Any]


def init_mlp_params(key, cfg, d_ff: int) -> Params:
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    if cfg.act == "silu":   # SwiGLU: gate, up, down
        p = {
            "w_gate": dense_init(ks[0], d, d_ff, dtype),
            "w_up":   dense_init(ks[1], d, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d, dtype, scale=d_ff ** -0.5),
        }
    else:                    # 2-matrix MLP (gelu / relu_sq)
        p = {
            "w_up":   dense_init(ks[0], d, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d, dtype, scale=d_ff ** -0.5),
        }
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def mlp_forward(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    act = act_fn(cfg.act)
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = act(h)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y
