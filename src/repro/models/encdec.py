"""Whisper-style encoder-decoder transformer (audio family).

The conv audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings [B, F, d_model] (``input_specs`` supplies them).

Serving: encoder runs once; cross-attention K/V are computed once per layer
(static cache).  The decoder self-attention cache is dense or SWAN-hybrid.
Beyond-paper extension (SwanConfig.compress_cross_attn): the static
cross-attn K/V can be winnowed once at encode time — a pure memory win since
those entries are never "recent context" (no ring buffer needed).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import absorb as absorb_mod
from repro.core import hybrid_cache as hc
from repro.core import swan_attention as swa
from repro.core.winnow import rotate_k, rotate_q, winnow_vector, unpack_dense, dequantize_int8
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import apply_norm, embed_init, init_norm, split_keys
from repro.models.transformer import _swan_layer_decode, _swan_layer_prefill
from repro.sharding.api import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _enc_layer(key, cfg) -> Params:
    ks = split_keys(key, 4)
    return {"ln1": init_norm(ks[0], cfg, cfg.d_model),
            "attn": attn.init_attn_params(ks[1], cfg),
            "ln2": init_norm(ks[2], cfg, cfg.d_model),
            "mlp": mlp_mod.init_mlp_params(ks[3], cfg, cfg.d_ff)}


def _dec_layer(key, cfg) -> Params:
    ks = split_keys(key, 6)
    return {"ln1": init_norm(ks[0], cfg, cfg.d_model),
            "attn": attn.init_attn_params(ks[1], cfg),
            "ln_x": init_norm(ks[2], cfg, cfg.d_model),
            "cross": attn.init_attn_params(ks[3], cfg),
            "ln2": init_norm(ks[4], cfg, cfg.d_model),
            "mlp": mlp_mod.init_mlp_params(ks[5], cfg, cfg.d_ff)}


def init_lm_params(key, cfg) -> Params:
    ks = split_keys(key, 8)
    enc_layers = [_enc_layer(k, cfg) for k in
                  split_keys(ks[0], cfg.n_encoder_layers)]
    dec_layers = [_dec_layer(k, cfg) for k in split_keys(ks[1], cfg.n_layers)]
    stack = lambda ls: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ls)
    return {
        "enc": {"pos_embed": embed_init(ks[2], cfg.encoder_seq, cfg.d_model,
                                        jnp.dtype(cfg.param_dtype)),
                "layers": stack(enc_layers),
                "ln_f": init_norm(ks[3], cfg, cfg.d_model)},
        "dec": {"embed": embed_init(ks[4], cfg.vocab_size, cfg.d_model,
                                    jnp.dtype(cfg.param_dtype)),
                "pos_embed": embed_init(ks[5], cfg.max_position_learned(),
                                        cfg.d_model, jnp.dtype(cfg.param_dtype)),
                "layers": stack(dec_layers),
                "ln_f": init_norm(ks[6], cfg, cfg.d_model)},
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def encode(p: Params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames [B, F, d] (stub embeddings) -> encoder output [B, F, d]."""
    B, F, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + p["enc"]["pos_embed"][None, :F].astype(x.dtype)
    x = shard(x, "enc_out")

    def body(x, lp):
        h = apply_norm(lp["ln1"], cfg, x)
        h = attn.attn_forward(lp["attn"], cfg, h, None, causal=False)
        x = x + h
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, apply_norm(lp["ln2"], cfg, x))
        return x + h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p["enc"]["layers"])
    return apply_norm(p["enc"]["ln_f"], cfg, x)


def _dec_layer_fwd(lp: Params, cfg, x, positions, enc_out):
    h = apply_norm(lp["ln1"], cfg, x)
    h = attn.attn_forward(lp["attn"], cfg, h, positions)
    x = x + h
    h = apply_norm(lp["ln_x"], cfg, x)
    h = attn.attn_forward(lp["cross"], cfg, h, None, kv_x=enc_out)
    x = x + h
    h = mlp_mod.mlp_forward(lp["mlp"], cfg, apply_norm(lp["ln2"], cfg, x))
    return x + h


def lm_forward(p: Params, cfg, tokens: jnp.ndarray,
               frames: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc_out = encode(p, cfg, frames)
    B, S = tokens.shape
    x = jnp.take(p["dec"]["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = x + jnp.take(p["dec"]["pos_embed"],
                     jnp.minimum(positions, p["dec"]["pos_embed"].shape[0] - 1),
                     axis=0).astype(x.dtype)
    x = shard(x, "residual")

    def body(x, lp):
        return _dec_layer_fwd(lp, cfg, x, positions, enc_out), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, p["dec"]["layers"])
    x = apply_norm(p["dec"]["ln_f"], cfg, x)
    logits = x @ p["dec"]["embed"].T.astype(x.dtype)    # whisper ties head
    return shard(logits, "logits"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# SWAN calibration for decoder self-attention
# ---------------------------------------------------------------------------

def collect_qkv(p: Params, cfg, tokens: jnp.ndarray, frames: jnp.ndarray):
    enc_out = encode(p, cfg, frames)
    B, S = tokens.shape
    x = jnp.take(p["dec"]["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = x + jnp.take(p["dec"]["pos_embed"],
                     jnp.minimum(positions, p["dec"]["pos_embed"].shape[0] - 1),
                     axis=0).astype(x.dtype)

    def body(x, lp):
        h = apply_norm(lp["ln1"], cfg, x)
        cap = attn.project_qkv(lp["attn"], cfg, h, positions)
        return _dec_layer_fwd(lp, cfg, x, positions, enc_out), cap

    _, (q, k, v) = jax.lax.scan(body, x, p["dec"]["layers"])
    return q, k, v, p["dec"]["layers"]["attn"]["wo"]


def absorb_swan(p: Params, cfg, projections: Params) -> Params:
    out = {"enc": p["enc"], "dec": dict(p["dec"])}
    layers = dict(p["dec"]["layers"])
    layers["attn"] = absorb_mod.absorb_vo(layers["attn"], projections["p_vo"],
                                          cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    out["dec"]["layers"] = layers
    return out


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_serve_state(cfg, swan, batch: int, max_seq: int) -> Params:
    L = cfg.n_layers
    use_swan = swan is not None and swan.enabled
    if use_swan:
        self_c = hc.init_swan_cache(cfg, swan, batch, max_seq)
    else:
        self_c = attn.init_dense_cache(cfg, batch, max_seq)
    bcast = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (L, *x.shape)), t)
    Kv, dh, F = cfg.n_kv_heads, cfg.d_head, cfg.encoder_seq
    if use_swan and swan.compress_cross_attn:
        cross = {"k": hc._side(batch, Kv, F, swan.k_max, hc._val_dtype(cfg, swan), swan),
                 "v": hc._side(batch, Kv, F, swan.k_max, hc._val_dtype(cfg, swan), swan)}
    else:
        cross = {"k": jnp.zeros((batch, Kv, F, dh), jnp.dtype(cfg.dtype)),
                 "v": jnp.zeros((batch, Kv, F, dh), jnp.dtype(cfg.dtype))}
    return {"self": bcast(self_c), "cross": bcast(cross)}


def _cross_kv(lp: Params, cfg, enc_out: jnp.ndarray):
    B, F, _ = enc_out.shape
    k = enc_out @ lp["wk"]
    v = enc_out @ lp["wv"]
    if "bk" in lp:
        k, v = k + lp["bk"], v + lp["bv"]
    k = k.reshape(B, F, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(B, F, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    return k, v   # [B, Kv, F, dh]


def _cross_attend(lp: Params, cfg, x: jnp.ndarray, cross: Params) -> jnp.ndarray:
    """Decode-time cross attention against the (possibly winnowed) cache."""
    B = x.shape[0]
    H, Kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ lp["wq"]
    if "bq" in lp:
        q = q + lp["bq"]
    q = q.reshape(B, -1, H, dh)
    if isinstance(cross["k"], dict):       # winnowed static cache
        def expand(side):
            vals = side["vals"]
            if "scale" in side:
                vals = dequantize_int8(vals, side["scale"], jnp.float32)
            return unpack_dense(vals.astype(jnp.float32), side.get("idx"), dh)
        kc, vc = expand(cross["k"]), expand(cross["v"])
    else:
        kc, vc = (cross["k"].astype(jnp.float32),
                  cross["v"].astype(jnp.float32))
    qh = q.reshape(B, -1, Kv, H // Kv, dh).transpose(0, 2, 3, 1, 4)  # [B,Kv,G,Sq,dh]
    s = jnp.einsum("bngqd,bnsd->bngqs", qh.astype(jnp.float32), kc) / math.sqrt(dh)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqs,bnsd->bngqd", w, vc)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, -1, H, dh).astype(x.dtype)
    return attn.output_proj(lp, o)


def prefill(p: Params, cfg, tokens: jnp.ndarray, state: Params,
            swan=None, projections=None, frames: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Params]:
    enc_out = encode(p, cfg, frames)
    B, S = tokens.shape
    x = jnp.take(p["dec"]["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = x + jnp.take(p["dec"]["pos_embed"],
                     jnp.minimum(positions, p["dec"]["pos_embed"].shape[0] - 1),
                     axis=0).astype(x.dtype)
    use_swan = swan is not None and swan.enabled
    pq = (projections["p_qk"] if use_swan
          else jnp.zeros((cfg.n_layers, 1), jnp.float32))

    def body(x, xs):
        lp, st, pq_l = xs
        new_st = dict(st)
        h = apply_norm(lp["ln1"], cfg, x)
        if use_swan:
            h, new_st["self"] = _swan_layer_prefill(lp, pq_l, st["self"], cfg,
                                                    swan, h, positions)
        else:
            q, k, v = attn.project_qkv(lp["attn"], cfg, h, positions)
            new_st["self"] = attn.dense_cache_insert(st["self"], k, v, 0)
            o = attn.dense_attention(q, k, v, None, causal=True) \
                if S <= attn.DENSE_ATTN_MAX_SEQ else \
                attn.blocked_attention(q, k, v, causal=True)
            h = attn.output_proj(lp["attn"], o)
        x = x + h
        # build (and optionally winnow) the static cross cache
        kc, vc = _cross_kv(lp["cross"], cfg, enc_out)
        if isinstance(st["cross"]["k"], dict):
            new_st["cross"] = {
                "k": dict(winnow_vector(kc, swan, "k")),
                "v": dict(winnow_vector(vc, swan, "v")),
            }
        else:
            new_st["cross"] = {"k": kc.astype(st["cross"]["k"].dtype),
                               "v": vc.astype(st["cross"]["v"].dtype)}
        h = apply_norm(lp["ln_x"], cfg, x)
        h = _cross_attend(lp["cross"], cfg, h, new_st["cross"])
        x = x + h
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, apply_norm(lp["ln2"], cfg, x))
        return x + h, new_st

    # note: _swan_layer_prefill / decode use lp["attn"] internally
    x, state = jax.lax.scan(body, x, (p["dec"]["layers"], state, pq))
    x = apply_norm(p["dec"]["ln_f"], cfg, x[:, -1:])
    return x @ p["dec"]["embed"].T.astype(x.dtype), state


def decode_step(p: Params, cfg, token: jnp.ndarray, pos, state: Params,
                swan=None, projections=None) -> Tuple[jnp.ndarray, Params]:
    B = token.shape[0]
    pos = hc.per_seq_pos(pos, B)
    x = jnp.take(p["dec"]["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    pe = jnp.take(p["dec"]["pos_embed"],
                  jnp.minimum(pos, p["dec"]["pos_embed"].shape[0] - 1), axis=0)
    x = x + pe[:, None].astype(x.dtype)
    use_swan = swan is not None and swan.enabled
    pq = (projections["p_qk"] if use_swan
          else jnp.zeros((cfg.n_layers, 1), jnp.float32))

    def body(x, xs):
        lp, st, pq_l = xs
        new_st = dict(st)
        h = apply_norm(lp["ln1"], cfg, x)
        if use_swan:
            h, new_st["self"] = _swan_layer_decode(lp, pq_l, st["self"], cfg,
                                                   swan, h, pos)
        else:
            h, new_st["self"] = attn.attn_decode_dense(lp["attn"], cfg, h,
                                                       pos, st["self"])
        x = x + h
        h = apply_norm(lp["ln_x"], cfg, x)
        h = _cross_attend(lp["cross"], cfg, h, st["cross"])
        x = x + h
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, apply_norm(lp["ln2"], cfg, x))
        return x + h, new_st

    x, state = jax.lax.scan(body, x, (p["dec"]["layers"], state, pq))
    x = apply_norm(p["dec"]["ln_f"], cfg, x)
    return (x @ p["dec"]["embed"].T.astype(x.dtype))[:, 0], state
