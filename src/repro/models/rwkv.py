"""RWKV-6 (Finch) — attention-free time-mix with data-dependent decay.

Per head (dk = dv = head_dim):

  y_t = r_t · (diag(u)·k_t v_tᵀ + S_t) ;   S_{t+1} = diag(w_t)·S_t + k_t v_tᵀ

with the Finch hallmark: the per-channel decay w_t = exp(−exp(base + LoRA(x)))
is *data-dependent*.  Training uses a chunked formulation (like mamba.py):
per-channel cumulative log-decays give an attention-like intra-chunk kernel
plus an O(1) carried state — no token-level scan in the compiled graph.

Simplifications vs the full Finch recipe (documented in DESIGN.md): static
learnable token-shift mixing coefficients (Finch uses data-dependent ddlerp);
everything else (decay LoRA, bonus u, per-head GroupNorm, receptance-gated
squared-ReLU channel-mix) is faithful.

SWAN is inapplicable (no KV cache); serve state is O(1).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys

Params = Dict[str, Any]

CHUNK = 64
DECAY_LORA = 64


def init_time_mix_params(key, cfg) -> Params:
    d = cfg.d_model
    H, dk = cfg.n_heads, cfg.rwkv.head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 8)
    decay_speed = jnp.array(
        [-6.0 + 5.0 * (i / max(d - 1, 1)) ** 0.9 for i in range(d)], jnp.float32)
    return {
        "mix": jnp.full((5, d), 0.5, jnp.float32),    # r,k,v,g,w shift mixes
        "w_r":  dense_init(ks[0], d, d, dtype),
        "w_k6": dense_init(ks[1], d, d, dtype),
        "w_v6": dense_init(ks[2], d, d, dtype),
        "w_g":  dense_init(ks[3], d, d, dtype),
        "w_o6": dense_init(ks[4], d, d, dtype, scale=d ** -0.5),
        "decay_w": decay_speed,                        # base log-log decay
        "decay_lora_a": dense_init(ks[5], d, DECAY_LORA, dtype),
        "decay_lora_b": dense_init(ks[6], DECAY_LORA, d, dtype, scale=0.01),
        "bonus_u": jnp.zeros((H, dk), jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        "gn_bias": jnp.zeros((d,), jnp.float32),
    }


def init_channel_mix_params(key, cfg) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    return {
        "mix": jnp.full((2, d), 0.5, jnp.float32),     # k, r shift mixes
        "w_up":   dense_init(ks[0], d, ff, dtype),
        "w_down": dense_init(ks[1], ff, d, dtype, scale=ff ** -0.5),
        "w_r":    dense_init(ks[2], d, d, dtype),
    }


def _shift_mix(x: jnp.ndarray, x_prev: jnp.ndarray, mix: jnp.ndarray):
    """lerp(x, token-shifted x, mix).  x: [B,S,d]; x_prev: [B,1,d] carry."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    return x + (shifted - x) * mix[None, None]


def _heads(x: jnp.ndarray, H: int, dk: int) -> jnp.ndarray:
    B, S, _ = x.shape
    return x.reshape(B, S, H, dk)


def _group_norm(y: jnp.ndarray, p: Params, eps: float = 64e-5) -> jnp.ndarray:
    """Per-head LayerNorm over dv (RWKV's GroupNorm(H))  y: [B,S,H,dv]."""
    mu = y.mean(-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    B, S, H, dv = y.shape
    yn = yn.reshape(B, S, H * dv)
    return yn * p["gn_scale"][None, None] + p["gn_bias"][None, None]


def _rkvgw(p: Params, cfg, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Projections with token shift.  Returns r,k,v [B,S,H,dk], g [B,S,d],
    logw [B,S,H,dk] (negative log decays)."""
    H, dk = cfg.n_heads, cfg.rwkv.head_dim
    mix = p["mix"].astype(x.dtype)
    xr = _shift_mix(x, x_prev, mix[0])
    xk = _shift_mix(x, x_prev, mix[1])
    xv = _shift_mix(x, x_prev, mix[2])
    xg = _shift_mix(x, x_prev, mix[3])
    xw = _shift_mix(x, x_prev, mix[4])
    r = _heads(xr @ p["w_r"], H, dk)
    k = _heads(xk @ p["w_k6"], H, dk)
    v = _heads(xv @ p["w_v6"], H, dk)
    g = jax.nn.silu(xg @ p["w_g"])
    lora = jnp.tanh(xw @ p["decay_lora_a"]) @ p["decay_lora_b"]
    logw = -jnp.exp(p["decay_w"][None, None].astype(jnp.float32) +
                    lora.astype(jnp.float32))           # [B,S,d], < 0
    return r, k, v, g, _heads(logw, H, dk)


def _chunk_wkv(r, k, v, logw, u, h0):
    """One chunk.  r,k,v,logw: [B,Q,H,dk] (f32); u: [H,dk]; h0: [B,H,dk,dv].
    Returns (y [B,Q,H,dv], h_out)."""
    B, Q, H, dk = r.shape
    cum = jnp.cumsum(logw, axis=1)                        # Σ_{s<=t} logw_s
    cum_prev = cum - logw                                 # Σ_{s<t}  logw_s
    # intra-chunk kernel: A[t,τ] = Σ_i r_t[i] k_τ[i] exp(cum_prev_t − cum_τ)[i], τ<t
    rel = cum_prev[:, :, None] - cum[:, None, :]          # [B,t,τ,H,dk]
    decay = jnp.exp(rel)
    strict = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    A = jnp.einsum("bthi,bshi,btshi->bhts", r, k,
                   jnp.where(strict[None, :, :, None, None], decay, 0.0))
    y = jnp.einsum("bhts,bshj->bthj", A, v)
    # diagonal bonus term
    y = y + jnp.einsum("bthi,hi,bthi,bthj->bthj", r, u, k, v)
    # inter-chunk state contribution
    y = y + jnp.einsum("bthi,bthi,bhij->bthj", r, jnp.exp(cum_prev), h0)
    # carried state
    w_tail = jnp.exp(cum[:, -1:, :, :] - cum)             # Π_{s>τ} w_s
    h_out = h0 * jnp.exp(cum[:, -1])[..., None] + \
        jnp.einsum("bshi,bshi,bshj->bhij", w_tail, k, v)
    return y, h_out


def time_mix_forward(p: Params, cfg, x: jnp.ndarray, chunk: int = CHUNK,
                     return_state: bool = False):
    """x: [B,S,d] -> [B,S,d] (training / prefill).

    ``return_state=True`` also returns the final recurrent state (used by
    the parallel prefill to seed subsequent decode).  Tail padding is
    state-safe by construction: zero-padded k contributes nothing and
    zero-padded logw means decay exp(0)=1 (identity transition).
    """
    B, S, d = x.shape
    H, dk = cfg.n_heads, cfg.rwkv.head_dim
    x_prev = jnp.zeros((B, 1, d), x.dtype)
    r, k, v, g, logw = _rkvgw(p, cfg, x, x_prev)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["bonus_u"].astype(jnp.float32)

    nb = -(-S // chunk)
    pad = nb * chunk - S
    if pad:
        padfn = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        rf, kf, vf, logw = padfn(rf), padfn(kf), padfn(vf), padfn(logw)
    resh = lambda t: t.reshape(B, nb, chunk, H, dk).transpose(1, 0, 2, 3, 4)

    def step(h, inp):
        rc, kc, vc, wc = inp
        y, h = _chunk_wkv(rc, kc, vc, wc, u, h)
        return h, y

    h0 = jnp.zeros((B, H, dk, dk), jnp.float32)
    h_fin, ys = jax.lax.scan(step, h0, (resh(rf), resh(kf), resh(vf), resh(logw)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nb * chunk, H, dk)[:, :S]
    y = _group_norm(y, p).astype(x.dtype)
    out = (y * g) @ p["w_o6"]
    if return_state:
        return out, h_fin
    return out


def channel_mix_forward(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    B, S, d = x.shape
    x_prev = jnp.zeros((B, 1, d), x.dtype)
    mix = p["mix"].astype(x.dtype)
    xk = _shift_mix(x, x_prev, mix[0])
    xr = _shift_mix(x, x_prev, mix[1])
    h = jnp.square(jax.nn.relu(xk @ p["w_up"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (h @ p["w_down"])


# ---------------------------------------------------------------------------
# Decode (recurrent)
# ---------------------------------------------------------------------------

def init_rwkv_state(cfg, batch: int) -> Params:
    H, dk = cfg.n_heads, cfg.rwkv.head_dim
    d = cfg.d_model
    return {
        "S": jnp.zeros((batch, H, dk, dk), jnp.float32),
        "x_tm": jnp.zeros((batch, 1, d), jnp.dtype(cfg.dtype)),
        "x_cm": jnp.zeros((batch, 1, d), jnp.dtype(cfg.dtype)),
    }


def time_mix_decode(p: Params, cfg, x: jnp.ndarray,
                    state: Params) -> Tuple[jnp.ndarray, Params]:
    """x: [B,1,d] one token."""
    r, k, v, g, logw = _rkvgw(p, cfg, x, state["x_tm"])
    rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))  # [B,H,dk]
    w = jnp.exp(logw[:, 0])                                        # [B,H,dk]
    u = p["bonus_u"].astype(jnp.float32)
    S = state["S"]
    kv = kf[..., :, None] * vf[..., None, :]                       # [B,H,dk,dv]
    y = jnp.einsum("bhi,bhij->bhj", rf, u[None, :, :, None] * kv + S)
    S_new = S * w[..., None] + kv
    y = _group_norm(y[:, None], p).astype(x.dtype)
    out = (y * g) @ p["w_o6"]
    return out, {**state, "S": S_new, "x_tm": x}


def channel_mix_decode(p: Params, cfg, x: jnp.ndarray,
                       state: Params) -> Tuple[jnp.ndarray, Params]:
    mix = p["mix"].astype(x.dtype)
    shifted = state["x_cm"]
    xk = x + (shifted - x) * mix[0][None, None]
    xr = x + (shifted - x) * mix[1][None, None]
    h = jnp.square(jax.nn.relu(xk @ p["w_up"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (h @ p["w_down"])
    return out, {**state, "x_cm": x}
