from repro.models.registry import ModelApi, get_model, swan_applicable  # noqa: F401
