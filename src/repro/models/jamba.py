"""Jamba: hybrid Mamba + attention + MoE decoder (1:7 attn:mamba, MoE e=2).

Layers are organised in groups of ``attn_period`` (8): within a group the
pattern is static (attention at ``attn_offset``, mamba elsewhere; MoE on odd
global indices), so the model scans over *groups* with the 8 sub-layers
unrolled — compact HLO for 72 layers, heterogeneous structure preserved.

SWAN applies to the attention layers only (all sequence-proportional state);
mamba layers keep O(1) recurrent state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import absorb as absorb_mod
from repro.core import hybrid_cache as hc
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import apply_norm, embed_init, init_norm, split_keys
from repro.models.transformer import (_swan_layer_decode, _swan_layer_prefill)
from repro.sharding.api import shard

Params = Dict[str, Any]


def _group_size(cfg) -> int:
    return cfg.attn_period


def n_groups(cfg) -> int:
    assert cfg.n_layers % _group_size(cfg) == 0
    return cfg.n_layers // _group_size(cfg)


def init_group(key, cfg, g: int) -> Params:
    P = _group_size(cfg)
    ks = split_keys(key, P)
    group: Params = {}
    for pidx in range(P):
        li = g * P + pidx
        lks = split_keys(ks[pidx], 4)
        lp: Params = {"ln1": init_norm(lks[0], cfg, cfg.d_model),
                      "ln2": init_norm(lks[2], cfg, cfg.d_model)}
        if cfg.layer_kind(li) == "attn":
            lp["attn"] = attn.init_attn_params(lks[1], cfg)
        else:
            lp["mamba"] = mb.init_mamba_params(lks[1], cfg)
        if cfg.ffn_kind(li) == "moe":
            lp["experts"] = moe_mod.init_moe_params(lks[3], cfg)
        else:
            lp["mlp"] = mlp_mod.init_mlp_params(lks[3], cfg, cfg.d_ff)
        group[f"pos{pidx}"] = lp
    return group


def init_lm_params(key, cfg) -> Params:
    G = n_groups(cfg)
    ks = split_keys(key, G + 3)
    groups = [init_group(ks[g], cfg, g) for g in range(G)]
    return {
        "embed": embed_init(ks[-3], cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.param_dtype)),
        "groups": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups),
        "ln_f": init_norm(ks[-2], cfg, cfg.d_model),
        "head": embed_init(ks[-1], cfg.vocab_size, cfg.d_model,
                           jnp.dtype(cfg.param_dtype)).T,
    }


def _sublayer(lp: Params, cfg, x, positions, aux):
    h = apply_norm(lp["ln1"], cfg, x)
    if "attn" in lp:
        h = attn.attn_forward(lp["attn"], cfg, h, positions)
    else:
        h = mb.mamba_forward(lp["mamba"], cfg, h)
    x = shard(x + h, "residual")
    h = apply_norm(lp["ln2"], cfg, x)
    if "experts" in lp:
        h, a = moe_mod.moe_forward(lp["experts"], cfg, h)
        aux = aux + a["moe_load_balance"] + a["moe_router_z"]
    else:
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, h)
    return shard(x + h, "residual"), aux


def lm_forward(p: Params, cfg, tokens: jnp.ndarray,
               prefix_embeds=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    P = _group_size(cfg)

    def body(carry, gp):
        x, aux = carry
        for pidx in range(P):
            x, aux = _sublayer(gp[f"pos{pidx}"], cfg, x, positions, aux)
        return (x, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               p["groups"])
    x = apply_norm(p["ln_f"], cfg, x)
    return shard(x @ p["head"].astype(x.dtype), "logits"), aux


# ---------------------------------------------------------------------------
# SWAN calibration (attention layers only)
# ---------------------------------------------------------------------------

def collect_qkv(p: Params, cfg, tokens: jnp.ndarray, prefix_embeds=None):
    """Returns per-attention-layer (q, k, v, wo) stacked over groups."""
    B, S = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    P = _group_size(cfg)
    apos = cfg.attn_offset

    def body(carry, gp):
        x, aux = carry
        cap = None
        for pidx in range(P):
            lp = gp[f"pos{pidx}"]
            if pidx == apos:
                h = apply_norm(lp["ln1"], cfg, x)
                cap = attn.project_qkv(lp["attn"], cfg, h, positions)
            x, aux = _sublayer(lp, cfg, x, positions, aux)
        return (x, aux), cap

    (_, _), (q, k, v) = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), p["groups"])
    wo = p["groups"][f"pos{apos}"]["attn"]["wo"]
    return q, k, v, wo


def absorb_swan(p: Params, cfg, projections: Params) -> Params:
    apos = cfg.attn_offset
    out = dict(p)
    groups = dict(p["groups"])
    gp = dict(groups[f"pos{apos}"])
    gp["attn"] = absorb_mod.absorb_vo(gp["attn"], projections["p_vo"],
                                      cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    groups[f"pos{apos}"] = gp
    out["groups"] = groups
    return out


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_serve_state(cfg, swan, batch: int, max_seq: int) -> Params:
    G = n_groups(cfg)
    P = _group_size(cfg)
    use_swan = swan is not None and swan.enabled
    if use_swan:
        acache = hc.init_swan_cache(cfg, swan, batch, max_seq)
    else:
        acache = attn.init_dense_cache(cfg, batch, max_seq)
    mstate = mb.init_mamba_state(cfg, batch)
    state: Params = {"attn": jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (G, *x.shape)), acache)}
    for pidx in range(P):
        if pidx != cfg.attn_offset:
            state[f"mamba{pidx}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (G, *x.shape)), mstate)
    return state


def _ffn(lp, cfg, x):
    h = apply_norm(lp["ln2"], cfg, x)
    if "experts" in lp:
        # serving: no-drop dispatch (prefill ≡ incremental decode)
        h, _ = moe_mod.moe_forward(lp["experts"], cfg, h, no_drop=True)
    else:
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, h)
    return x + h


def prefill(p: Params, cfg, tokens: jnp.ndarray, state: Params,
            swan=None, projections=None, prefix_embeds=None
            ) -> Tuple[jnp.ndarray, Params]:
    B, S = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    P = _group_size(cfg)
    apos = cfg.attn_offset
    use_swan = swan is not None and swan.enabled
    pq = (projections["p_qk"] if use_swan
          else jnp.zeros((n_groups(cfg), 1), jnp.float32))

    def body(x, xs):
        gp, st, pq_g = xs
        new_st = dict(st)
        for pidx in range(P):
            lp = gp[f"pos{pidx}"]
            h = apply_norm(lp["ln1"], cfg, x)
            if pidx == apos:
                if use_swan:
                    h, new_st["attn"] = _swan_layer_prefill(
                        lp, pq_g, st["attn"], cfg, swan, h, positions)
                else:
                    q, k, v = attn.project_qkv(lp["attn"], cfg, h, positions)
                    new_st["attn"] = attn.dense_cache_insert(st["attn"], k, v, 0)
                    if S > attn.DENSE_ATTN_MAX_SEQ:
                        o = attn.blocked_attention(q, k, v, causal=True)
                    else:
                        o = attn.dense_attention(q, k, v, None, causal=True)
                    h = attn.output_proj(lp["attn"], o)
            else:
                h = mb.mamba_forward(lp["mamba"], cfg, h)
                # rebuild the recurrent state as if prefill ran sequentially
                new_st[f"mamba{pidx}"] = _mamba_state_from_prefill(
                    lp["mamba"], cfg, apply_norm(lp["ln1"], cfg, x))
            x = x + h
            x = _ffn(lp, cfg, x)
        return x, new_st

    x, state = jax.lax.scan(body, x, (p["groups"], state, pq))
    x = apply_norm(p["ln_f"], cfg, x[:, -1:])
    return x @ p["head"].astype(x.dtype), state


def _mamba_state_from_prefill(mp: Params, cfg, x: jnp.ndarray) -> Params:
    """Run the chunked scan once more, keeping only the final state + conv tail."""
    B, S, d = x.shape
    m = cfg.mamba
    xz = x @ mp["w_in"]
    u, _ = jnp.split(xz, 2, axis=-1)
    upad = jnp.pad(u, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    conv_tail = upad[:, -(m.d_conv - 1):] if m.d_conv > 1 else upad[:, :0]
    uc = sum(upad[:, i:i + S] * mp["conv_w"][i][None, None]
             for i in range(m.d_conv)) + mp["conv_b"]
    uc = jax.nn.silu(uc)
    dt, Bm, Cm = mb._ssm_inputs(mp, cfg, uc)
    A = -jnp.exp(mp["a_log"])
    h = jnp.zeros((B, m.expand * d, m.d_state), jnp.float32)
    chunk = mb.CHUNK
    nb = -(-S // chunk)
    pad = nb * chunk - S
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        uc = jnp.pad(uc, ((0, 0), (0, pad), (0, 0)))
    resh = lambda t: t.reshape(B, nb, chunk, -1).transpose(1, 0, 2, 3)

    def step(h, inp):
        dt_c, B_c, C_c, u_c = inp
        _, h = mb._chunk_scan(dt_c, A, B_c, C_c, u_c.astype(jnp.float32), h)
        return h, None

    h, _ = jax.lax.scan(step, h, (resh(dt), resh(Bm), resh(Cm), resh(uc)))
    return {"h": h, "conv": conv_tail.astype(jnp.dtype(cfg.dtype))}


def decode_step(p: Params, cfg, token: jnp.ndarray, pos, state: Params,
                swan=None, projections=None) -> Tuple[jnp.ndarray, Params]:
    x = jnp.take(p["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    P = _group_size(cfg)
    apos = cfg.attn_offset
    use_swan = swan is not None and swan.enabled
    pq = (projections["p_qk"] if use_swan
          else jnp.zeros((n_groups(cfg), 1), jnp.float32))

    def body(x, xs):
        gp, st, pq_g = xs
        new_st = dict(st)
        for pidx in range(P):
            lp = gp[f"pos{pidx}"]
            h = apply_norm(lp["ln1"], cfg, x)
            if pidx == apos:
                if use_swan:
                    h, new_st["attn"] = _swan_layer_decode(
                        lp, pq_g, st["attn"], cfg, swan, h, pos)
                else:
                    h, new_st["attn"] = attn.attn_decode_dense(
                        lp["attn"], cfg, h, pos, st["attn"])
            else:
                h, new_st[f"mamba{pidx}"] = mb.mamba_decode_step(
                    lp["mamba"], cfg, h, st[f"mamba{pidx}"])
            x = x + h
            x = _ffn(lp, cfg, x)
        return x, new_st

    x, state = jax.lax.scan(body, x, (p["groups"], state, pq))
    x = apply_norm(p["ln_f"], cfg, x)
    return (x @ p["head"].astype(x.dtype))[:, 0], state
