"""RWKV-6 full model: embed -> [time-mix + channel-mix] x L -> head.

No KV cache exists; serving state is O(1) per layer (SWAN inapplicable —
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import rwkv
from repro.models.common import apply_norm, embed_init, init_norm, split_keys
from repro.sharding.api import shard

Params = Dict[str, Any]


def init_layer(key, cfg) -> Params:
    ks = split_keys(key, 4)
    return {
        "ln1": init_norm(ks[0], cfg, cfg.d_model),
        "tm": rwkv.init_time_mix_params(ks[1], cfg),
        "ln2": init_norm(ks[2], cfg, cfg.d_model),
        "cm": rwkv.init_channel_mix_params(ks[3], cfg),
    }


def init_lm_params(key, cfg) -> Params:
    ks = split_keys(key, cfg.n_layers + 3)
    layers = [init_layer(ks[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-3], cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.param_dtype)),
        "layers": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers),
        "ln_f": init_norm(ks[-2], cfg, cfg.d_model),
        "head": embed_init(ks[-1], cfg.vocab_size, cfg.d_model,
                           jnp.dtype(cfg.param_dtype)).T,
    }


def lm_forward(p: Params, cfg, tokens: jnp.ndarray,
               prefix_embeds=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "residual")

    def body(carry, lp):
        x, = carry
        x = x + rwkv.time_mix_forward(lp["tm"], cfg, apply_norm(lp["ln1"], cfg, x))
        x = shard(x, "residual")
        x = x + rwkv.channel_mix_forward(lp["cm"], cfg, apply_norm(lp["ln2"], cfg, x))
        return (shard(x, "residual"),), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x,), _ = jax.lax.scan(body_fn, (x,), p["layers"])
    x = apply_norm(p["ln_f"], cfg, x)
    return shard(x @ p["head"].astype(x.dtype), "logits"), jnp.zeros((), jnp.float32)


def init_serve_state(cfg, swan, batch: int, max_seq: int) -> Params:
    if swan is not None and swan.enabled:
        raise ValueError("SWAN is inapplicable to rwkv6 (no KV cache); "
                         "see DESIGN.md §Arch-applicability")
    one = rwkv.init_rwkv_state(cfg, batch)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)), one)


def decode_step(p: Params, cfg, token: jnp.ndarray, pos, states: Params,
                swan=None, projections=None) -> Tuple[jnp.ndarray, Params]:
    x = jnp.take(p["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))

    def body(x, xs):
        lp, st = xs
        h, st = rwkv.time_mix_decode(lp["tm"], cfg, apply_norm(lp["ln1"], cfg, x), st)
        x = x + h
        h, st = rwkv.channel_mix_decode(lp["cm"], cfg, apply_norm(lp["ln2"], cfg, x), st)
        return x + h, st

    x, states = jax.lax.scan(body, x, (p["layers"], states))
    x = apply_norm(p["ln_f"], cfg, x)
    return (x @ p["head"].astype(x.dtype))[:, 0], states


def prefill(p: Params, cfg, tokens: jnp.ndarray, states: Params,
            swan=None, projections=None, prefix_embeds=None
            ) -> Tuple[jnp.ndarray, Params]:
    """Parallel (chunked) prefill: one forward pass rebuilds every layer's
    recurrent state — O(S·chunk) work instead of a 32k-step token scan."""
    B, S = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "residual")

    def body(x, xs):
        lp, st = xs
        new_st = dict(st)
        xin = apply_norm(lp["ln1"], cfg, x)
        h, S_fin = rwkv.time_mix_forward(lp["tm"], cfg, xin, return_state=True)
        new_st["S"] = S_fin
        new_st["x_tm"] = xin[:, -1:]
        x = x + h
        xin = apply_norm(lp["ln2"], cfg, x)
        h = rwkv.channel_mix_forward(lp["cm"], cfg, xin)
        new_st["x_cm"] = xin[:, -1:]
        return x + h, new_st

    x, states = jax.lax.scan(body, x, (p["layers"], states))
    x = apply_norm(p["ln_f"], cfg, x[:, -1:])
    return x @ p["head"].astype(x.dtype), states
