"""Decoder-only transformer LM (dense / MoE / VLM families).

Pure-functional: parameters are nested dicts; homogeneous layer stacks are
scanned (stacked [L, ...] leaves, MaxText-style) so 126-layer configs lower
to compact HLO.  Supports:

  * training forward (+ MoE aux losses) with remat,
  * VLM prefix embeddings (internvl2: stub patch embeddings),
  * SWAN calibration capture (``collect_qkv``) and weight absorption,
  * serving: prefill + decode with dense or SWAN hybrid caches.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import absorb as absorb_mod
from repro.core import hybrid_cache as hc
from repro.core import paged_cache as pc
from repro.core import swan_attention as swa
from repro.core.winnow import rotate_k, rotate_q
from repro.kernels.dispatch import pallas_decode_supported
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models.common import (apply_norm, embed_init, init_norm,
                                 split_keys)
from repro.sharding.api import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _remat(body, cfg):
    """Remat policy: 'full' recomputes everything in bwd (min memory, but
    FSDP parameter all-gathers re-run in the bwd pass); 'dots' saves matmul
    operands (incl. gathered weights) — trades temp memory for collective
    traffic (§Perf cell B iteration)."""
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(body)


def init_layer_params(key, cfg, layer_idx: int = 0) -> Params:
    ks = split_keys(key, 4)
    p: Params = {
        "ln1": init_norm(ks[0], cfg, cfg.d_model),
        "attn": attn.init_attn_params(ks[1], cfg),
        "ln2": init_norm(ks[2], cfg, cfg.d_model),
    }
    if cfg.ffn_kind(layer_idx) == "moe":
        p["experts"] = moe_mod.init_moe_params(ks[3], cfg)
    else:
        p["mlp"] = mlp_mod.init_mlp_params(ks[3], cfg, cfg.d_ff)
    return p


def init_lm_params(key, cfg) -> Params:
    """All layers homogeneous here (dense / all-MoE); jamba overrides."""
    ks = split_keys(key, cfg.n_layers + 3)
    layers = [init_layer_params(ks[i], cfg, i) for i in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    p: Params = {
        "embed": embed_init(ks[-3], cfg.vocab_size, cfg.d_model,
                            jnp.dtype(cfg.param_dtype)),
        "layers": stacked,
        "ln_f": init_norm(ks[-2], cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(ks[-1], cfg.vocab_size, cfg.d_model,
                               jnp.dtype(cfg.param_dtype)).T
    if cfg.pos == "learned":
        p["pos_embed"] = embed_init(ks[-1], cfg.max_position_learned(),
                                    cfg.d_model, jnp.dtype(cfg.param_dtype))
    return p


def abstract_params(cfg):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda: init_lm_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# Layer forward
# ---------------------------------------------------------------------------

def layer_forward(lp: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm block.  Returns (x, moe_aux_scalar)."""
    h = apply_norm(lp["ln1"], cfg, x)
    h = attn.attn_forward(lp["attn"], cfg, h, positions)
    x = shard(x + h, "residual")
    h = apply_norm(lp["ln2"], cfg, x)
    if "experts" in lp:
        h, aux = moe_mod.moe_forward(lp["experts"], cfg, h)
        aux_sum = aux["moe_load_balance"] + aux["moe_router_z"]
    else:
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, h)
        aux_sum = jnp.zeros((), jnp.float32)
    x = shard(x + h, "residual")
    return x, aux_sum


def _embed_inputs(p: Params, cfg, tokens: jnp.ndarray,
                  prefix_embeds: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos == "learned":
        x = x + jnp.take(p["pos_embed"], jnp.minimum(
            positions, p["pos_embed"].shape[0] - 1), axis=0).astype(x.dtype)
    return shard(x, "residual"), positions


def lm_forward(p: Params, cfg, tokens: jnp.ndarray,
               prefix_embeds: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B, S] (+ optional prefix embeds [B, P, d]) -> (logits, aux)."""
    x, positions = _embed_inputs(p, cfg, tokens, prefix_embeds)

    def body(carry, lp):
        x, aux = carry
        x, a = layer_forward(lp, cfg, x, positions)
        return (x, aux + a), None

    body_fn = _remat(body, cfg)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   p["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], p["layers"])
            (x, aux), _ = body_fn((x, aux), lp)

    x = apply_norm(p["ln_f"], cfg, x)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    logits = shard(x @ head.astype(x.dtype), "logits")
    return logits, aux


def lm_loss(p: Params, cfg, batch: Dict[str, jnp.ndarray]
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (prefix positions excluded for VLM)."""
    tokens = batch["tokens"]
    logits, aux = lm_forward(p, cfg, tokens, batch.get("prefix_embeds"))
    n_prefix = 0 if batch.get("prefix_embeds") is None else batch["prefix_embeds"].shape[1]
    logits = logits[:, n_prefix:]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(gold) if mask is None else mask[:, 1:].astype(jnp.float32)
    nll = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    zloss = 1e-4 * ((logz ** 2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = nll + zloss + aux
    return loss, {"nll": nll, "aux": aux, "z": zloss}


# ---------------------------------------------------------------------------
# SWAN calibration + absorption
# ---------------------------------------------------------------------------

def collect_qkv(p: Params, cfg, tokens: jnp.ndarray,
                prefix_embeds: Optional[jnp.ndarray] = None):
    """Run the model, capturing per-layer post-RoPE q/k and v (paper §4.1.1).

    Returns (q [L,B,S,H,dh], k [L,B,S,Kv,dh], v [L,B,S,Kv,dh], wo [L,H·dh,d]).
    """
    x, positions = _embed_inputs(p, cfg, tokens, prefix_embeds)

    def body(carry, lp):
        x, _ = carry
        h = apply_norm(lp["ln1"], cfg, x)
        q, k, v = attn.project_qkv(lp["attn"], cfg, h, positions)
        x, _ = layer_forward(lp, cfg, x, positions)
        return (x, jnp.zeros((), jnp.float32)), (q, k, v)

    (_, _), (q, k, v) = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                     p["layers"])
    return q, k, v, p["layers"]["attn"]["wo"]


def absorb_swan(p: Params, cfg, projections: Params) -> Params:
    """Fold P_VO into the stacked attention weights (lossless, Lemma A.2)."""
    out = dict(p)
    layers = dict(p["layers"])
    layers["attn"] = absorb_mod.absorb_vo(
        p["layers"]["attn"], projections["p_vo"],
        cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    out["layers"] = layers
    return out


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def init_caches(cfg, swan, batch: int, max_seq: int) -> Params:
    """Stacked [L, ...] caches; ``swan`` None -> dense baseline cache."""
    if swan is None or not swan.enabled:
        one = attn.init_dense_cache(cfg, batch, max_seq)
    else:
        one = hc.init_swan_cache(cfg, swan, batch, max_seq)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)), one)


def init_paged_caches(cfg, swan, batch: int, max_seq: int, n_pages: int,
                      page_size: int) -> Params:
    """Paged serve state (repro.core.paged_cache): per-layer sparse sides
    become a shared page pool [L, n_pages, Kv, page_size, k]; the dense
    ring buffers stay per-slot.  The page table rides along as a separate
    traced operand (host-owned mapping, see repro.runtime.page_pool)."""
    if swan is None or not swan.enabled:
        raise ValueError("paged caches require SWAN (the sparse sides are "
                         "what gets paged); use init_caches for dense")
    if max_seq % page_size:
        raise ValueError(f"max_seq={max_seq} not divisible by "
                         f"page_size={page_size}")
    Kv, dh, b = cfg.n_kv_heads, cfg.d_head, swan.buffer
    one = {
        "pool": pc.init_paged_pool(cfg, swan, n_pages, page_size),
        "buf_k": jnp.zeros((batch, Kv, b, dh), jnp.dtype(cfg.dtype)),
        "buf_v": jnp.zeros((batch, Kv, b, dh), jnp.dtype(cfg.dtype)),
        "buf_pos": jnp.full((batch, b), -1, jnp.int32),
    }
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)), one)


def _swan_seq_ctx():
    """(mesh, seq_axis) for split-S swan decode, from the installed rules."""
    from repro.sharding.api import current_rules
    rules = current_rules()
    if rules is None:
        return None, None
    spec = rules.kinds.get("swan_sparse")
    if spec is None or len(spec) < 3 or spec[2] is None:
        return None, None
    return rules.mesh, spec[2]


def _swan_layer_decode(lp: Params, p_qk_l: jnp.ndarray, cache_l: Params,
                       cfg, swan, x: jnp.ndarray, pos,
                       k_act=None, page_tab=None, use_pallas: bool = False,
                       pallas_interpret: Optional[bool] = None
                       ) -> Tuple[jnp.ndarray, Params]:
    """``use_pallas`` (STATIC bool) dispatches the attention read to the
    fused Pallas kernels (repro.kernels.swan_decode) instead of the
    pure-JAX gather/scatter path; cache INSERTION stays pure JAX either
    way (a tiny lane-local scatter XLA handles fine — only the bulk read
    is bandwidth-bound).  The kernel is lane-local, so it composes with
    the engine's batch-sharded shard_map; split-S sequence sharding keeps
    the pure-JAX flash-decoding path (the kernel has no cross-shard stat
    merge), as do the truncate mode and bt=0 ablations
    (``pallas_decode_supported``)."""
    B = x.shape[0]
    Kv, G, dh = cfg.n_kv_heads, cfg.q_group, cfg.d_head
    pos = hc.per_seq_pos(pos, B)                                 # [B]
    positions = pos[:, None]                                     # [B, 1]
    q, k, v = attn.project_qkv(lp["attn"], cfg, x, positions)   # v̂ already rotated (absorbed)
    q_hat = rotate_q(q, p_qk_l, Kv)[:, 0]                        # [B,Kv,G,dh]
    k_hat = rotate_k(k, p_qk_l)                                  # [B,1→S dim,Kv,dh]
    mesh, seq_axis = _swan_seq_ctx()
    kern = use_pallas and mesh is None and pallas_decode_supported(swan)
    if page_tab is None:
        cache_l = hc.swan_cache_insert_decode(cache_l, swan, cfg, k_hat, v,
                                              pos, k_act=k_act)
        if kern and cache_l["k"]["vals"].shape[2] > 0:
            from repro.kernels.swan_decode import ops as sdk
            o = sdk.swan_decode_from_cache(q_hat, cache_l, swan, pos,
                                           interpret=pallas_interpret)
        else:
            o = swa.swan_decode_attention(q_hat, cache_l, swan, cfg, pos,
                                          mesh=mesh, seq_axis=seq_axis)
    else:
        cache_l = pc.paged_insert_decode(cache_l, swan, cfg, k_hat, v, pos,
                                         page_tab, k_act=k_act)
        if kern and page_tab.shape[1] > 0:
            from repro.kernels.swan_decode import ops as sdk
            o = sdk.swan_decode_paged_from_cache(q_hat, cache_l, swan, pos,
                                                 page_tab,
                                                 interpret=pallas_interpret)
        else:
            o = swa.swan_decode_attention_paged(q_hat, cache_l, swan, cfg,
                                                pos, page_tab, mesh=mesh,
                                                seq_axis=seq_axis)
    o = o.reshape(B, 1, Kv * G, dh)
    return attn.output_proj(lp["attn"], o), cache_l


def _swan_layer_prefill(lp: Params, p_qk_l, cache_l, cfg, swan,
                        x: jnp.ndarray, positions,
                        k_act=None, true_len=None) -> Tuple[jnp.ndarray, Params]:
    """Prefill: dense (lossless, Lemma A.1) attention on rotated q̂/k̂/v̂;
    hybrid cache populated for subsequent decode."""
    B, S, _ = x.shape
    Kv, G, dh = cfg.n_kv_heads, cfg.q_group, cfg.d_head
    q, k, v = attn.project_qkv(lp["attn"], cfg, x, positions)
    q_hat = rotate_q(q, p_qk_l, Kv).reshape(B, S, Kv * G, dh)
    k_hat = rotate_k(k, p_qk_l)
    cache_l = hc.swan_cache_insert_prefill(cache_l, swan, cfg, k_hat, v,
                                           k_act=k_act, true_len=true_len)
    if S > attn.DENSE_ATTN_MAX_SEQ:
        o = attn.blocked_attention(q_hat, k_hat, v, causal=True)
    else:
        o = attn.dense_attention(q_hat, k_hat, v, mask=None, causal=True)
    return attn.output_proj(lp["attn"], o), cache_l


def _swan_scan_xs(cfg, swan, projections, use_swan):
    """Per-layer scan inputs: projections + (adaptive) per-layer k_active.
    projections may carry 'k_layer' [L] from repro.core.adaptive."""
    if not use_swan:
        z = jnp.zeros((cfg.n_layers, 1), jnp.float32)
        return z, jnp.zeros((cfg.n_layers,), jnp.int32)
    pq = projections["p_qk"]
    k_layer = projections.get("k_layer")
    if k_layer is None:
        k_layer = jnp.full((cfg.n_layers,), swan.kk, jnp.int32)
    return pq, jnp.asarray(k_layer, jnp.int32)


def _layer_ffn(lp: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    h = apply_norm(lp["ln2"], cfg, x)
    if "experts" in lp:
        # serving: no-drop dispatch (prefill ≡ incremental decode)
        h, _ = moe_mod.moe_forward(lp["experts"], cfg, h, no_drop=True)
    else:
        h = mlp_mod.mlp_forward(lp["mlp"], cfg, h)
    return x + h


def lm_prefill(p: Params, cfg, tokens: jnp.ndarray, caches: Params,
               swan=None, projections: Optional[Params] = None,
               prefix_embeds: Optional[jnp.ndarray] = None,
               k_active=None, true_len=None) -> Tuple[jnp.ndarray, Params]:
    """Process the prompt; fill caches.  Returns (last-token logits, caches).

    ``k_active``: optional traced scalar overriding the SWAN runtime
    retention for this whole prompt (per-request k — the serve engine
    prefills one request at a time, so a scalar suffices here).

    ``true_len``: optional traced scalar — the real prompt length when
    ``tokens`` is padded to a compile bucket (prompt-length bucketing).
    Logits are then taken at position true_len - 1, and the hybrid-cache
    ring is anchored at true_len; padding junk beyond it only ever lands
    in masked/invalid cache regions (causal masking keeps it out of the
    prefill attention)."""
    x, positions = _embed_inputs(p, cfg, tokens, prefix_embeds)
    use_swan = swan is not None and swan.enabled

    def body(x, xs):
        lp, cache_l, p_qk_l, k_l = xs
        h = apply_norm(lp["ln1"], cfg, x)
        if use_swan:
            h, cache_l = _swan_layer_prefill(lp, p_qk_l, cache_l, cfg, swan,
                                             h, positions, k_act=k_l,
                                             true_len=true_len)
        else:
            q, k, v = attn.project_qkv(lp["attn"], cfg, h, positions)
            cache_l = attn.dense_cache_insert(cache_l, k, v, 0)
            if x.shape[1] > attn.DENSE_ATTN_MAX_SEQ:
                o = attn.blocked_attention(q, k, v, causal=True)
            else:
                o = attn.dense_attention(q, k, v, mask=None, causal=True)
            h = attn.output_proj(lp["attn"], o)
        x = shard(x + h, "residual")
        x = shard(_layer_ffn(lp, cfg, x), "residual")
        return x, cache_l

    pq, k_arr = _swan_scan_xs(cfg, swan, projections, use_swan)
    if use_swan and k_active is not None:
        k_arr = jnp.minimum(k_arr, jnp.asarray(k_active, jnp.int32))
    x, caches = jax.lax.scan(body, x, (p["layers"], caches, pq, k_arr))
    if true_len is None:
        x = x[:, -1:]
    else:   # bucketed prompt: last REAL token, not the padding tail
        x = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(true_len, jnp.int32) - 1, 1, axis=1)
    x = apply_norm(p["ln_f"], cfg, x)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    return x @ head.astype(x.dtype), caches


def _swan_layer_prefill_chunk(lp: Params, p_qk_l, cache_l: Params, cfg, swan,
                              x: jnp.ndarray, slot, start, true_len,
                              positions, k_act=None, page_tab=None,
                              prefix_len: Optional[int] = None,
                              use_pallas: bool = False,
                              pallas_interpret: Optional[bool] = None
                              ) -> Tuple[jnp.ndarray, Params]:
    """One layer of BATCHED chunked prefill against the batched serve
    state: gather the P selected slots' lanes (traced ``slot [P]``), attend
    each lane to its [winnowed sparse prefix ‖ ring ‖ chunk], commit each
    chunk at its own offset, and scatter the lanes back.  Only the selected
    lanes (and, paged, their own pages) are touched — decode steps for
    other slots interleave freely between chunks.  Dead lanes (``slot >=
    n_slots``, padding of a partially filled prefill batch) gather clamped
    garbage that is computed but never written: slab/ring scatters drop
    out-of-range lanes, paged writes are redirected to the trash page."""
    Kv = cfg.n_kv_heads
    n_slots = cache_l["buf_pos"].shape[0]
    q, k, v = attn.project_qkv(lp["attn"], cfg, x, positions)
    q_hat = rotate_q(q, p_qk_l, Kv)                      # [P,S,Kv,G,dh]
    k_hat = rotate_k(k, p_qk_l)
    lane_ix = jnp.minimum(slot, n_slots - 1)             # clamped gather
    ring = {n: cache_l[n][lane_ix] for n in ("buf_k", "buf_v", "buf_pos")}
    out_l = dict(cache_l)
    kern = (use_pallas and pallas_decode_supported(swan)
            and _swan_seq_ctx()[0] is None)

    def bulk_q():
        # the bulk-stats kernel consumes the query-flattened layout that
        # swan_chunk_prefill_attention uses internally: [P, Kv, S·G, dh]
        P_, S_, Kv_, G_, dh_ = q_hat.shape
        qf = q_hat.astype(jnp.float32).transpose(0, 2, 1, 3, 4)
        return qf.reshape(P_, Kv_, S_ * G_, dh_)

    if page_tab is None:                                 # slab layout
        view = dict(ring)
        for n in ("k", "v"):
            # attend to a STATIC power-of-two prefix of the slab rows (the
            # caller buckets max(start)+S up): the bulk read's transient
            # then follows the prompts so far, not max_seq — one executable
            # per (P, chunk, prefix) bucket, O(log³) total
            pl = (min(prefix_len, cache_l[n]["vals"].shape[2])
                  if prefix_len is not None else cache_l[n]["vals"].shape[2])
            view[n] = jax.tree_util.tree_map(
                lambda a: jax.lax.slice_in_dim(a, 0, pl, axis=2)[lane_ix],
                cache_l[n])
        stats = None
        if kern and view["k"]["vals"].shape[2] > 0:
            from repro.kernels.flash_prefill import swan_chunk as sck
            sp_len = jnp.maximum(start - swan.buffer, 0)
            stats = sck.swan_chunk_stats_pallas(
                bulk_q(), view["k"]["vals"], view["k"]["idx"],
                view["v"]["vals"], view["v"]["idx"], sp_len,
                k_scale=view["k"].get("scale"),
                v_scale=view["v"].get("scale"),
                interpret=pallas_interpret)
        o = swa.swan_chunk_prefill_attention(q_hat, k_hat, v, view, swan,
                                             cfg, start, true_len,
                                             sparse_stats=stats)
        dest, packed_k, packed_v, upd = hc.chunk_evict_winnow(
            ring, swan, k_hat, v, start, true_len, k_act=k_act)
        ring_new = {**ring, **upd}
        out_l["k"] = hc.write_sparse_rows(cache_l["k"], packed_k, slot, dest)
        out_l["v"] = hc.write_sparse_rows(cache_l["v"], packed_v, slot, dest)
    else:                                                # paged layout
        page_rows = page_tab[lane_ix]                    # [P, Pg]
        lane = dict(ring)
        lane["pool"] = cache_l["pool"]
        if kern and page_rows.shape[1] > 0:
            # pool pages feed the kernel's VMEM tiles directly: no
            # paged_logical_view materialisation on the chunk path either
            from repro.kernels.flash_prefill import swan_chunk as sck
            pk, pv = cache_l["pool"]["k"], cache_l["pool"]["v"]
            sp_len = jnp.maximum(start - swan.buffer, 0)
            stats = sck.swan_chunk_stats_paged_pallas(
                bulk_q(), pk["vals"], pk["idx"], pv["vals"], pv["idx"],
                sp_len, page_rows,
                pool_k_scale=pk.get("scale"), pool_v_scale=pv.get("scale"),
                interpret=pallas_interpret)
            o = swa.swan_chunk_prefill_attention(q_hat, k_hat, v, ring,
                                                 swan, cfg, start, true_len,
                                                 sparse_stats=stats)
        else:
            view = swa.paged_logical_view(lane, page_rows)
            o = swa.swan_chunk_prefill_attention(q_hat, k_hat, v, view,
                                                 swan, cfg, start, true_len)
        lane = pc.paged_insert_prefill_chunk(lane, swan, cfg, k_hat, v,
                                             start, true_len, page_rows,
                                             k_act=k_act,
                                             dead=slot >= n_slots)
        out_l["pool"] = lane["pool"]
        ring_new = {n: lane[n] for n in ("buf_k", "buf_v", "buf_pos")}
    for n in ("buf_k", "buf_v", "buf_pos"):
        out_l[n] = cache_l[n].at[slot].set(
            ring_new[n].astype(cache_l[n].dtype), mode="drop")
    return attn.output_proj(lp["attn"], o), out_l


def _dense_layer_prefill_chunk(lp: Params, cache_l: Params, cfg,
                               x: jnp.ndarray, slot, start, positions,
                               prefix_len: Optional[int] = None
                               ) -> Tuple[jnp.ndarray, Params]:
    """Batched chunked prefill for the dense-cache baseline: insert each
    lane's chunk K/V at [start_p, start_p+S) in its slot's lane, then
    causal attention of each chunk against its lane's first ``prefix_len``
    rows (a static bucket >= max(start) + S; rows past a lane's chunk are
    masked by the per-lane causal offset)."""
    n_slots = cache_l["k"].shape[0]
    q, k, v = attn.project_qkv(lp["attn"], cfg, x, positions)
    cache_l = attn.dense_cache_insert_rows(cache_l, k, v, slot, start)
    lane_ix = jnp.minimum(slot, n_slots - 1)
    pl = (min(prefix_len, cache_l["k"].shape[2])
          if prefix_len is not None else cache_l["k"].shape[2])
    view = jax.tree_util.tree_map(
        lambda a: jax.lax.slice_in_dim(a, 0, pl, axis=2)[lane_ix], cache_l)
    kc = view["k"].transpose(0, 2, 1, 3)                 # [P, pl, Kv, dh]
    vc = view["v"].transpose(0, 2, 1, 3)
    if kc.shape[1] > attn.DENSE_ATTN_MAX_SEQ:
        o = attn.blocked_attention(q, kc, vc, causal=True, q_offset=start)
    else:
        o = attn.dense_attention(q, kc, vc, mask=None, causal=True,
                                 q_offset=start)
    return attn.output_proj(lp["attn"], o), cache_l


def lm_prefill_chunk_batched(p: Params, cfg, tokens: jnp.ndarray,
                             caches: Params, slot, start, swan=None,
                             projections: Optional[Params] = None,
                             k_active=None, true_len=None, page_tab=None,
                             prefix_len: Optional[int] = None,
                             use_pallas: bool = False,
                             pallas_interpret: Optional[bool] = None
                             ) -> Tuple[jnp.ndarray, Params]:
    """Advance up to P slots' prefills by one chunk EACH against the
    engine's BATCHED serve state — ONE executable per step no matter how
    many prefills are in flight (batched concurrent chunked prefill).

    ``tokens [P, C]``: the packed chunks, one lane per in-flight prefill,
    padded to a power-of-two width C; ``slot`` / ``start`` / ``true_len``
    (and per-request ``k_active``) are traced int32 [P] — each lane's slot
    index in the batched state, the absolute position of its chunk's first
    token, and its number of real chunk tokens.  P is a power-of-two
    bucket: lanes past the selected prefills are DEAD (``slot = n_slots``,
    out of range) — they compute clamped garbage whose writes are dropped
    (slab/ring) or land on the trash page (paged).  One executable serves
    every (P, C) bucket pair, so admission bursts compile O(log n_slots ×
    log chunk) shapes, not one per combination of in-flight prefills.

    Each lane's chunk attends causally to [its already-cached tokens ‖
    chunk]: with SWAN, positions [0, start_p) are seen exactly as a decode
    step at the same position sees them (winnowed sparse prefix + dense
    ring) while in-chunk positions stay dense, and the hybrid cache is
    advanced so that after the chunk the ring holds [start + true_len - b,
    start + true_len) — indistinguishable at the boundary from a monolithic
    prefill of start + true_len tokens.  ``page_tab [n_slots, Pg]`` (a
    power-of-two page-table prefix; lanes gather their own rows by slot)
    routes sparse reads/writes through the shared page pool instead.

    ``prefix_len`` (STATIC python int >= max(start) + C,
    power-of-two-bucketed by the caller) bounds the attention read to each
    lane's first slab/dense rows, so the bulk-read transient follows the
    prompts so far instead of max_seq (the paged layout is already bounded
    by its shipped ``page_tab`` prefix).

    ``use_pallas`` / ``pallas_interpret`` (STATIC): run the sparse-prefix
    bulk read through the Pallas bulk-chunk kernel
    (repro.kernels.flash_prefill.swan_chunk) — packed vectors expand once
    in VMEM, and the paged variant gathers pool pages in-kernel instead of
    materialising ``paged_logical_view``.

    VLM prefix embeddings are not supported on the chunked path (the
    engine's monolithic admission handles those prompts).

    Batch-shardability (audited for the mesh-sharded serve engine, which
    runs this function inside ``shard_map`` over the data axis): every op
    here is lane-local — lanes only ever index the batched state through
    their own ``slot`` entry, all reductions run over sequence/head/vocab
    dims, and there are no cross-lane collectives.  The per-shard call is
    therefore bit-identical to a single-device call on the shard's local
    block, with ``slot`` given as SHARD-LOCAL lane indices: dead-lane
    parking stays correct per shard because the parking value and the
    clamped gather both derive from the LOCAL batch size
    (``cache_l["buf_pos"].shape[0]``), and paged trash redirection targets
    the shard's own local page 0.

    Returns (logits at each chunk's last real token [P, V], caches) —
    dead lanes' logits are garbage the caller discards.
    """
    P, S = tokens.shape
    start = hc.per_seq_pos(start, P)
    true_len = (jnp.full((P,), S, jnp.int32) if true_len is None
                else hc.per_seq_pos(true_len, P))
    use_swan = swan is not None and swan.enabled
    if page_tab is not None and not use_swan:
        raise ValueError("page_tab given but SWAN disabled — only the "
                         "sparse sides are paged")
    x = jnp.take(p["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    positions = start[:, None] + jnp.arange(S)[None]     # [P, S]
    if cfg.pos == "learned":
        x = x + jnp.take(p["pos_embed"], jnp.minimum(
            positions, p["pos_embed"].shape[0] - 1), axis=0).astype(x.dtype)
    x = shard(x, "residual")
    k_req = None if k_active is None else hc.per_seq_pos(k_active, P)

    def body(x, xs):
        lp, cache_l, p_qk_l, k_l = xs
        h = apply_norm(lp["ln1"], cfg, x)
        if use_swan:
            k_eff = k_l if k_req is None else jnp.minimum(k_l, k_req)
            h, cache_l = _swan_layer_prefill_chunk(
                lp, p_qk_l, cache_l, cfg, swan, h, slot, start, true_len,
                positions, k_act=k_eff, page_tab=page_tab,
                prefix_len=prefix_len, use_pallas=use_pallas,
                pallas_interpret=pallas_interpret)
        else:
            h, cache_l = _dense_layer_prefill_chunk(lp, cache_l, cfg, h,
                                                    slot, start, positions,
                                                    prefix_len=prefix_len)
        x = shard(x + h, "residual")
        x = shard(_layer_ffn(lp, cfg, x), "residual")
        return x, cache_l

    pq, k_arr = _swan_scan_xs(cfg, swan, projections, use_swan)
    x, caches = jax.lax.scan(body, x, (p["layers"], caches, pq, k_arr))
    x = jnp.take_along_axis(                             # last REAL token
        x, jnp.maximum(true_len - 1, 0)[:, None, None], axis=1)
    x = apply_norm(p["ln_f"], cfg, x)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    return (x @ head.astype(x.dtype))[:, 0], caches


def lm_decode_step(p: Params, cfg, token: jnp.ndarray, pos, caches: Params,
                   swan=None, projections: Optional[Params] = None,
                   k_active=None, page_tab=None, use_pallas: bool = False,
                   pallas_interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, Params]:
    """token [B] -> (logits [B, V], updated caches).

    ``pos``: scalar int32 (lockstep batch) or per-sequence [B] (continuous
    batching).  ``k_active``: optional traced scalar or per-sequence [B]
    SWAN retention override — per-request runtime-tunable compression; a
    traced operand, so mixed-k batches share one compiled executable.

    ``page_tab``: optional int32 [B, max_pages] page table — ``caches`` is
    then the paged layout from ``init_paged_caches`` and sparse reads/writes
    go through the shared page pool (repro.core.paged_cache).

    ``use_pallas`` / ``pallas_interpret`` (STATIC): dispatch the per-layer
    attention read to the fused Pallas kernels — slab tiles or, paged, the
    in-kernel page-table gather (see docs/kernels.md for the policy; the
    pure-JAX path remains the reference and the fallback).

    Batch-shardability (audited for the mesh-sharded serve engine): the
    decode step is lane-local end to end — per-sequence ``pos``/``k_active``
    index nothing but their own lane, dead lanes (pos < 0) drop their
    writes locally, the paged gather goes through the lane's own table row
    into its shard's block of the pool, and no reduction crosses the batch
    axis.  Running it inside ``shard_map`` over the data axis is therefore
    bit-identical to the single-device step on each shard's local block
    (the optional split-S collectives in swan_attention only arise when
    sharding rules put the SEQUENCE dim on a mesh axis, which the serve
    engine does not)."""
    B = token.shape[0]
    pos = hc.per_seq_pos(pos, B)
    x = jnp.take(p["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.pos == "learned":
        pe = jnp.take(p["pos_embed"],
                      jnp.minimum(pos, p["pos_embed"].shape[0] - 1), axis=0)
        x = x + pe[:, None].astype(x.dtype)
    use_swan = swan is not None and swan.enabled
    if page_tab is not None and not use_swan:
        raise ValueError("page_tab given but SWAN disabled — only the "
                         "sparse sides are paged")
    k_req = None if k_active is None else jnp.asarray(k_active, jnp.int32)

    def body(x, xs):
        lp, cache_l, p_qk_l, k_l = xs
        h = apply_norm(lp["ln1"], cfg, x)
        if use_swan:
            k_eff = k_l if k_req is None else jnp.minimum(k_l, k_req)
            h, cache_l = _swan_layer_decode(lp, p_qk_l, cache_l, cfg, swan,
                                            h, pos, k_act=k_eff,
                                            page_tab=page_tab,
                                            use_pallas=use_pallas,
                                            pallas_interpret=pallas_interpret)
        else:
            h, cache_l = attn.attn_decode_dense(lp["attn"], cfg, h, pos, cache_l)
        x = x + h
        x = _layer_ffn(lp, cfg, x)
        return x, cache_l

    pq, k_arr = _swan_scan_xs(cfg, swan, projections, use_swan)
    x, caches = jax.lax.scan(body, x, (p["layers"], caches, pq, k_arr))
    x = apply_norm(p["ln_f"], cfg, x)
    head = p["embed"].T if cfg.tie_embeddings else p["head"]
    return (x @ head.astype(x.dtype))[:, 0], caches
