"""Shared model building blocks: norms, RoPE, initialisers, dtype helpers.

All modules are pure functions over parameter pytrees (nested dicts of
jnp arrays).  Parameter creation is always via an ``init_*`` function taking
a PRNG key so that ``jax.eval_shape`` can derive abstract parameter trees
for the dry-run without allocating anything.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dt(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM inits)."""
    std = scale if scale is not None else d_in ** -0.5
    w = jax.random.truncated_normal(key, -3.0, 3.0, (d_in, d_out), jnp.float32) * std
    return w.astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def init_norm(key, cfg, d: int) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), dt(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dt(cfg.param_dtype)),
                "bias": jnp.zeros((d,), dt(cfg.param_dtype))}
    if cfg.norm == "nonparam_ln":   # OLMo: no learnable affine
        return {}
    raise ValueError(cfg.norm)


def apply_norm(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Normalise in fp32, cast back to activation dtype."""
    xdtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
        x = x * p["scale"].astype(jnp.float32)
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
        if cfg.norm == "layernorm":
            x = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return x.astype(xdtype)


# ---------------------------------------------------------------------------
# Rotary positional embedding (RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies [d_head//2], float32."""
    exponents = np.arange(0, d_head, 2, dtype=np.float32) / d_head
    return jnp.asarray(1.0 / (theta ** exponents))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply RoPE.  x: [..., seq, n_heads, d_head]; positions: [..., seq].

    Uses the "half-split" convention (llama): rotate pairs
    (x[..., :d/2], x[..., d/2:]).
    """
    d_head = x.shape[-1]
    inv = rope_freqs(d_head, theta)                    # [d/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # [..., S, 1, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def causal_mask(sq: int, sk: int, offset: int = 0) -> jnp.ndarray:
    """Boolean [sq, sk] mask; True = attend.  offset = key positions that
    precede the first query position (for chunked prefill)."""
    q_pos = jnp.arange(sq)[:, None] + offset
    k_pos = jnp.arange(sk)[None, :]
    return k_pos <= q_pos


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
