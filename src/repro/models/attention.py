"""Multi-head / grouped-query attention with RoPE and KV caches.

Three execution paths:
  * ``attn_forward``      — full (train / prefill / encoder) attention.
    Uses a memory-O(S·Bq) blocked online-softmax implementation for long
    sequences (pure JAX lax.scan; GSPMD-shardable) and plain dense attention
    for short ones.
  * ``attn_decode_dense`` — single-token decode against a dense KV cache.
  * SWAN decode lives in ``repro.core.swan_attention`` (hybrid cache).

Parameter layout (per layer):
  wq: [d, H*dh]   wk: [d, Kv*dh]   wv: [d, Kv*dh]   wo: [H*dh, d]
  (+ optional biases bq/bk/bv/bo)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, split_keys

Params = Dict[str, Any]

DENSE_ATTN_MAX_SEQ = 2048     # above this, use blocked attention
ATTN_BLOCK = 512              # kv block for blocked attention


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg) -> Params:
    d, H, Kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = split_keys(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "wq": dense_init(ks[0], d, H * dh, dtype),
        "wk": dense_init(ks[1], d, Kv * dh, dtype),
        "wv": dense_init(ks[2], d, Kv * dh, dtype),
        "wo": dense_init(ks[3], H * dh, d, dtype, scale=(H * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((Kv * dh,), dtype)
        p["bv"] = jnp.zeros((Kv * dh,), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def project_qkv(p: Params, cfg, x: jnp.ndarray,
                positions: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> q [B, S, H, dh], k/v [B, S, Kv, dh]; RoPE applied."""
    B, S, _ = x.shape
    H, Kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Kv, dh)
    v = v.reshape(B, S, Kv, dh)
    if cfg.pos == "rope" and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_proj(p: Params, o: jnp.ndarray) -> jnp.ndarray:
    """o: [B, S, H, dh] -> [B, S, d]."""
    B, S = o.shape[:2]
    y = o.reshape(B, S, -1) @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _expand_kv(k: jnp.ndarray, group: int) -> jnp.ndarray:
    """[B, S, Kv, dh] -> [B, S, Kv*G, dh] by repeating each kv head G times."""
    if group == 1:
        return k
    B, S, Kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, Kv, group, dh)).reshape(B, S, Kv * group, dh)


def dense_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    mask: Optional[jnp.ndarray], causal: bool,
                    q_offset=0) -> jnp.ndarray:
    """Plain softmax attention.  q: [B,Sq,H,dh], k/v: [B,Sk,Kv,dh].
    ``q_offset`` may be a scalar or per-sequence [B] (batched chunked
    prefill: each lane's chunk resumes at its own absolute position)."""
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    k = _expand_kv(k, H // Kv)
    v = _expand_kv(v, H // Kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(dh)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        off = jnp.asarray(q_offset)
        qp = jnp.arange(Sq)[None, :, None] + off.reshape(-1, 1, 1)  # [B|1,Sq,1]
        kp = jnp.arange(Sk)[None, None, :]
        scores = jnp.where((kp <= qp)[:, None], scores, neg)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :], scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return o


def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool, q_offset=0,
                      block: int = ATTN_BLOCK) -> jnp.ndarray:
    """Online-softmax attention, O(Sq·block) memory.  Pure JAX; shardable.
    ``q_offset`` may be a scalar or per-sequence [B], like
    ``dense_attention``.

    Scans over KV blocks carrying (m, l, acc) flash-attention stats.
    """
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    k = _expand_kv(k, H // Kv)
    v = _expand_kv(v, H // Kv)
    nb = -(-Sk // block)
    pad = nb * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nb, block, H, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block, H, dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / math.sqrt(dh)
    qf = q.astype(jnp.float32)
    off = jnp.asarray(q_offset)
    q_pos = jnp.arange(Sq)[None] + off.reshape(-1, 1)        # [B|1, Sq]

    def step(carry, inp):
        m, l, acc = carry
        bi, kblk, vblk = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kblk.astype(jnp.float32)) * scale
        k_pos = bi * block + jnp.arange(block)
        valid = (k_pos[None, None, :] < Sk)                  # [1, 1, block]
        if causal:
            valid = valid & (k_pos[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(valid[:, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[:, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nb), kb, vb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 2, 1, 3).astype(q.dtype)   # [B,Sq,H,dh]


def attn_forward(p: Params, cfg, x: jnp.ndarray,
                 positions: Optional[jnp.ndarray] = None,
                 causal: bool = True,
                 kv_x: Optional[jnp.ndarray] = None,
                 kv_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full attention forward.  ``kv_x`` given -> cross attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if kv_x is None:
        q, k, v = project_qkv(p, cfg, x, positions)
    else:
        q, _, _ = project_qkv(p, cfg, x, positions)
        # recompute: cross attention keys/values from encoder stream
        Sk = kv_x.shape[1]
        kf = kv_x @ p["wk"]
        vf = kv_x @ p["wv"]
        if "bk" in p:
            kf, vf = kf + p["bk"], vf + p["bv"]
        k = kf.reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
        v = vf.reshape(B, Sk, cfg.n_kv_heads, cfg.d_head)
        causal = False
    if max(q.shape[1], k.shape[1]) > DENSE_ATTN_MAX_SEQ:
        o = blocked_attention(q, k, v, causal=causal)
    else:
        o = dense_attention(q, k, v, mask=None, causal=causal)
    return output_proj(p, o)


# ---------------------------------------------------------------------------
# Dense KV cache (baseline decode path)
# ---------------------------------------------------------------------------

def init_dense_cache(cfg, batch: int, max_seq: int, dtype=None) -> Params:
    """Cache layout [B, Kv, S, dh]: S shards over 'model' for serving."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    Kv, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, Kv, max_seq, dh), dtype),
        "v": jnp.zeros((batch, Kv, max_seq, dh), dtype),
    }


def dense_cache_insert(cache: Params, k: jnp.ndarray, v: jnp.ndarray,
                       pos) -> Params:
    """Insert [B, S_new, Kv, dh] at position ``pos`` (scalar)."""
    kt = k.transpose(0, 2, 1, 3).astype(cache["k"].dtype)   # [B,Kv,S,dh]
    vt = v.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
    idx = (0, 0, pos, 0)
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], kt, idx),
        "v": jax.lax.dynamic_update_slice(cache["v"], vt, idx),
    }


def dense_cache_insert_rows(cache: Params, k: jnp.ndarray, v: jnp.ndarray,
                            lane: jnp.ndarray, start: jnp.ndarray) -> Params:
    """Insert chunk K/V [P, S, Kv, dh] at rows [start_p, start_p + S) of
    batch lanes ``lane`` [P] — the batched chunked-prefill insert (each
    in-flight prefill resumes at its own offset).  Dead lanes park out of
    range and are dropped, as are rows past max_seq."""
    S = k.shape[1]
    rows = start[:, None] + jnp.arange(S)[None]              # [P, S]
    li = lane[:, None]
    return {
        "k": cache["k"].at[li, :, rows].set(k.astype(cache["k"].dtype),
                                            mode="drop"),
        "v": cache["v"].at[li, :, rows].set(v.astype(cache["v"].dtype),
                                            mode="drop"),
    }


def dense_cache_insert_decode(cache: Params, k: jnp.ndarray, v: jnp.ndarray,
                              pos_b: jnp.ndarray) -> Params:
    """Insert one token per sequence ([B, 1, Kv, dh]) at per-sequence
    positions ``pos_b`` [B] (continuous batching: sequences decode at
    independent offsets).  Dead lanes (pos < 0: free slots and slots mid
    chunked-prefill, whose rows [0, start) hold REAL tokens) park at S and
    are dropped — a clamped negative index would clobber row 0."""
    S = cache["k"].shape[2]
    idx = jnp.where(pos_b >= 0, pos_b, S)
    bi = jnp.arange(pos_b.shape[0])
    kt = k.transpose(0, 2, 1, 3).astype(cache["k"].dtype)   # [B,Kv,1,dh]
    vt = v.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
    return {
        "k": cache["k"].at[bi, :, idx].set(kt[:, :, 0], mode="drop"),
        "v": cache["v"].at[bi, :, idx].set(vt[:, :, 0], mode="drop"),
    }


def attn_decode_dense(p: Params, cfg, x: jnp.ndarray, pos,
                      cache: Params) -> Tuple[jnp.ndarray, Params]:
    """One-token decode with dense cache.  x: [B, 1, d]; pos: scalar or [B]."""
    B = x.shape[0]
    H, Kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos[:, None]                                 # [B, 1]
    q, k, v = project_qkv(p, cfg, x, positions)
    cache = dense_cache_insert_decode(cache, k, v, pos)
    S = cache["k"].shape[2]
    kc = cache["k"]                                   # [B,Kv,S,dh] storage dtype
    vc = cache["v"]
    qh = q.reshape(B, Kv, H // Kv, dh)
    # cache operands stay in storage dtype (bf16): converting the whole
    # cache to f32 would double decode HBM traffic; dots accumulate f32.
    scores = jnp.einsum("bngd,bnsd->bngs", qh.astype(kc.dtype), kc,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    valid = jnp.arange(S)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bngs,bnsd->bngd", w.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, dh).astype(x.dtype)
    return output_proj(p, o), cache
