"""Mixture-of-experts FFN with routed + shared experts.

Dispatch is the capacity-based scatter/gather formulation (Switch-style,
without the O(T·E·C) one-hot dispatch tensor):

  1. router logits -> top-k experts per token
  2. position-in-expert via a cumulative sum over the flattened (token, slot)
     assignment order; tokens beyond an expert's capacity are dropped
  3. tokens scattered into an [E, C, d] buffer, batched expert matmuls,
     gathered back weighted by the (renormalised) gate values.

Expert weights carry a leading E axis so EP = shard that axis over 'model'
(XLA inserts the all-to-all equivalents around the scatter/gather).  For
expert counts not divisible by the mesh (qwen2-moe: 60), the expert axis is
replicated and the expert *hidden* axis is tensor-parallel instead.

Aux losses (load-balance + router z-loss) are returned for the train loss.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init, split_keys

Params = Dict[str, Any]


def init_moe_params(key, cfg) -> Params:
    m = cfg.moe
    d, dx = cfg.d_model, m.d_expert
    dtype = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 5)
    E = m.n_routed

    def stack(key, d_in, d_out, n, scale=None):
        keys = jax.random.split(key, n)
        return jnp.stack([dense_init(k, d_in, d_out, dtype, scale) for k in keys])

    p: Params = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": stack(ks[1], d, dx, E),
        "w_up":   stack(ks[2], d, dx, E),
        "w_down": stack(ks[3], dx, d, E, scale=dx ** -0.5),
    }
    if m.n_shared:
        sk = split_keys(ks[4], 3)
        S, ds = m.n_shared, m.n_shared * dx
        # shared experts fused into one wide FFN (equivalent & faster)
        p["shared"] = {
            "w_gate": dense_init(sk[0], d, ds, dtype),
            "w_up":   dense_init(sk[1], d, ds, dtype),
            "w_down": dense_init(sk[2], ds, d, dtype, scale=ds ** -0.5),
        }
    return p


def moe_forward(p: Params, cfg, x: jnp.ndarray, no_drop: bool = False
                ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: [B, S, d] -> (y [B, S, d], aux losses).

    ``no_drop=True`` (serving paths): for small token counts (decode steps,
    short prefills) capacity = T·K, so no token is ever dropped — makes
    prefill ≡ incremental decode exactly (capacity dropping is batch-order
    dependent: fine for training, breaks serving determinism).  For long
    prefills the exact bound would cost an O(T·K·E·d) buffer, so a doubled
    capacity factor is used instead (drops become vanishingly rare).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_routed, m.top_k
    act = act_fn(cfg.act)
    tokens = x.reshape(T, d)

    logits = tokens.astype(jnp.float32) @ p["router"]            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- capacity bookkeeping -----------------------------------------
    if no_drop and T * K <= 4096:
        C = T * K
    else:
        cf = m.capacity_factor * (2.0 if no_drop else 1.0)
        C = max(int(cf * T * K / E), 1)
    flat_e = gate_idx.reshape(-1)                                 # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # [T*K, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot                # 1-based ranks
    pos_in_e = (pos_in_e.sum(axis=-1) - 1)                        # [T*K]
    keep = pos_in_e < C
    # dropped tokens scatter to a sacrificial slot (C) that is sliced off
    safe_pos = jnp.where(keep, pos_in_e, C)

    token_ids = jnp.repeat(jnp.arange(T), K)                      # [T*K]
    buf = jnp.zeros((E, C + 1, d), tokens.dtype)
    buf = buf.at[flat_e, safe_pos].set(tokens[token_ids])
    buf = buf[:, :C]                                              # [E, C, d]

    # ---- expert computation (batched over E) ---------------------------
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # [E, C, d]

    # ---- gather back ----------------------------------------------------
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 1), (0, 0)))          # dropped -> 0
    gathered = out_buf[flat_e, safe_pos]                          # [T*K, d]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = gate_vals.reshape(-1)[:, None].astype(gathered.dtype)
    y = jax.ops.segment_sum(gathered * w, token_ids, num_segments=T)

    if m.n_shared:
        sp = p["shared"]
        hs = act(tokens @ sp["w_gate"]) * (tokens @ sp["w_up"])
        y = y + hs @ sp["w_down"]

    # ---- aux losses ------------------------------------------------------
    me = jnp.mean(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=(0, 1))  # fraction routed
    pe = jnp.mean(probs, axis=0)                                   # mean router prob
    aux_lb = E * jnp.sum(me * pe) * m.router_aux_weight
    aux_z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight
    aux = {"moe_load_balance": aux_lb, "moe_router_z": aux_z}
    return y.reshape(B, S, d), aux
