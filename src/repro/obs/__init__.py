"""repro.obs — dependency-free serving observability.

Two host-side primitives threaded through the serving stack:

* :mod:`repro.obs.metrics` — a :class:`MetricsRegistry` of counters,
  gauges and fixed-bucket histograms with Prometheus-text and JSON
  snapshot exporters.  Instrumentation lives entirely on the host side of
  every dispatch boundary: no wall-clock reads or metric updates ever
  happen inside jitted code, and device-side quantities are step-indexed
  (engine scheduler steps), never timed.
* :mod:`repro.obs.compile_events` — a process-global XLA compile
  counter fed by ``jax.monitoring`` backend-compile events.  Backs the
  engine's ``serve_compile_total`` counter and every zero-compile gate
  (warmup coverage, steady-state recompile checks): unlike jit-cache
  introspection it also sees eager one-off executables.
* :mod:`repro.obs.trace` — a structured JSONL event trace (admission,
  chunk dispatch, first token, decode dispatch, retirement, page
  map/free, pool grow/exhaustion, …) keyed by request uid and engine
  step, plus a wall-clock ``span`` helper for host-timing blocks and a
  :class:`StepProfiler` hook that brackets N engine steps with
  ``jax.profiler`` start/stop.

The contract the serve tests pin: metrics/tracing on vs off produces
IDENTICAL tokens and IDENTICAL dispatch counts — the subsystem observes
the engine, it never participates in it (tests/test_obs_engine.py).
"""
from repro.obs import compile_events
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_REGISTRY, NullRegistry,
                               parse_prometheus)
from repro.obs.trace import EventTrace, StepProfiler, span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "NULL_REGISTRY", "parse_prometheus", "EventTrace", "StepProfiler",
    "span", "compile_events",
]
