"""Dependency-free metrics registry: counters, gauges, fixed-bucket
histograms; Prometheus-text and JSON snapshot exporters.

Design constraints (serving-stack contract):

* HOST-SIDE ONLY.  Instruments are plain Python objects mutated by the
  scheduler between dispatches; nothing here is traced, jitted or placed
  on a device.  No instrument ever reads a wall clock — callers that want
  wall time use :func:`repro.obs.trace.span`; everything the engine
  records is step-indexed (engine scheduler steps), so metrics are
  deterministic across hosts.
* Fixed buckets.  Histograms take their bucket upper bounds at creation
  (power-of-two defaults suit step-indexed latencies); observations only
  bump integer counts, so snapshots are cheap and exact to re-serialize.
* Labels are plain keyword arguments; each distinct label set is its own
  series under the metric family, exactly as in Prometheus.

Round-trip guarantee (the CI schema-drift guard,
tests/test_obs.py): ``MetricsRegistry.from_snapshot(reg.snapshot())``
re-creates an identical registry, and every registered series appears in
``to_prometheus()`` output (``parse_prometheus`` reads it back).
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

_LabelKey = Tuple[Tuple[str, str], ...]

# The ONLY sanctioned ways to mint an instrument.  swanlint's obs rule
# (SWAN105, repro.analysis.lint) statically rejects ad-hoc module-level
# metric containers outside repro.obs — new counters/gauges/histograms
# must go through these idempotent getters so they land in the
# Prometheus/JSON exposition and the schema-drift guard.
REGISTRY_GETTERS = ("counter", "gauge", "histogram")


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter (``inc`` only)."""
    kind = "counter"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Point-in-time value (``set``/``inc``/``dec``)."""
    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` observations with
    ``value <= uppers[i]``, plus an overflow bucket (+Inf), an exact
    ``sum`` and a total ``count``."""
    kind = "histogram"
    __slots__ = ("uppers", "counts", "sum", "count")

    def __init__(self, buckets: Sequence[float]):
        if not buckets:
            raise ValueError("histogram needs at least one bucket bound")
        uppers = tuple(float(b) for b in buckets)
        if list(uppers) != sorted(set(uppers)):
            raise ValueError(f"buckets must be strictly increasing: {uppers}")
        self.uppers = uppers
        self.counts = [0] * (len(uppers) + 1)       # +1: overflow (+Inf)
        self.sum: float = 0.0
        self.count: int = 0

    def observe(self, v: float) -> None:
        i = 0
        for i, ub in enumerate(self.uppers):
            if v <= ub:
                break
        else:
            i = len(self.uppers)
        self.counts[i] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation; NaN when empty).  Good enough for
        periodic stats lines — exact percentiles come from the trace."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q={q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (self.uppers[i] if i < len(self.uppers)
                        else math.inf)
        return math.inf

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named families of instruments, each holding one series per label
    set.  Getter methods are idempotent: asking for an existing
    (name, labels) returns the same instrument; asking for an existing
    name with a different kind (or different histogram buckets) raises —
    a metric's schema is fixed at registration."""

    enabled = True

    def __init__(self) -> None:
        # name -> {"kind", "help", "buckets"?, "series": {labelkey: inst}}
        self._families: Dict[str, Dict[str, Any]] = {}

    # -- registration --------------------------------------------------

    def _family(self, name: str, kind: str, help: str,
                buckets: Optional[Sequence[float]]) -> Dict[str, Any]:
        fam = self._families.get(name)
        if fam is None:
            fam = {"kind": kind, "help": help, "series": {}}
            if kind == "histogram":
                fam["buckets"] = tuple(float(b) for b in buckets)
            self._families[name] = fam
            return fam
        if fam["kind"] != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{fam['kind']}, not {kind}")
        if kind == "histogram" and buckets is not None \
                and tuple(float(b) for b in buckets) != fam["buckets"]:
            raise ValueError(f"histogram {name!r} re-registered with "
                             "different buckets")
        if help and not fam["help"]:
            fam["help"] = help
        return fam

    def _series(self, name: str, kind: str, help: str,
                buckets: Optional[Sequence[float]], labels: Dict[str, Any]):
        fam = self._family(name, kind, help, buckets)
        key = _label_key(labels)
        inst = fam["series"].get(key)
        if inst is None:
            inst = (Histogram(fam["buckets"]) if kind == "histogram"
                    else _KINDS[kind]())
            fam["series"][key] = inst
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._series(name, "counter", help, None, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._series(name, "gauge", help, None, labels)

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "", **labels) -> Histogram:
        return self._series(name, "histogram", help, buckets, labels)

    # -- lookup --------------------------------------------------------

    def get(self, name: str, **labels):
        """Existing instrument for (name, labels), or None."""
        fam = self._families.get(name)
        if fam is None:
            return None
        return fam["series"].get(_label_key(labels))

    def value(self, name: str, default: float = 0, **labels) -> float:
        """Counter/gauge value for (name, labels); ``default`` if the
        series does not exist."""
        inst = self.get(name, **labels)
        if inst is None:
            return default
        if isinstance(inst, Histogram):
            raise TypeError(f"{name!r} is a histogram — read .count/.sum "
                            "or quantile() off get()")
        return inst.value

    def names(self) -> List[str]:
        return sorted(self._families)

    # -- exporters -----------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able full dump: every family, every series, exact state."""
        out: Dict[str, Any] = {}
        for name in sorted(self._families):
            fam = self._families[name]
            entry: Dict[str, Any] = {"kind": fam["kind"],
                                     "help": fam["help"], "series": []}
            if fam["kind"] == "histogram":
                entry["buckets"] = list(fam["buckets"])
            for key in sorted(fam["series"]):
                inst = fam["series"][key]
                ser: Dict[str, Any] = {"labels": dict(key)}
                if isinstance(inst, Histogram):
                    ser.update(counts=list(inst.counts), sum=inst.sum,
                               count=inst.count)
                else:
                    ser["value"] = inst.value
                entry["series"].append(ser)
            out[name] = entry
        return {"metrics": out}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    @classmethod
    def from_snapshot(cls, snap: Dict[str, Any]) -> "MetricsRegistry":
        """Inverse of :meth:`snapshot` — the round-trip the schema-drift
        test gates: ``from_snapshot(s).snapshot() == s``."""
        reg = cls()
        for name, fam in snap.get("metrics", {}).items():
            for ser in fam["series"]:
                labels = ser["labels"]
                if fam["kind"] == "histogram":
                    h = reg.histogram(name, fam["buckets"], fam["help"],
                                      **labels)
                    h.counts = list(ser["counts"])
                    h.sum = ser["sum"]
                    h.count = ser["count"]
                elif fam["kind"] == "counter":
                    reg.counter(name, fam["help"], **labels).value = \
                        ser["value"]
                else:
                    reg.gauge(name, fam["help"], **labels).set(ser["value"])
            # families registered with zero series survive the trip too
            if not fam["series"]:
                reg._family(name, fam["kind"], fam["help"],
                            fam.get("buckets"))
        return reg

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (counters/gauges verbatim;
        histograms as cumulative ``_bucket{le=}``/``_sum``/``_count``)."""
        lines: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['kind']}")
            for key in sorted(fam["series"]):
                inst = fam["series"][key]
                if isinstance(inst, Histogram):
                    cum = 0
                    for ub, c in zip(inst.uppers, inst.counts):
                        cum += c
                        lines.append(f"{name}_bucket"
                                     f"{_fmt_labels(key, le=_fmt_num(ub))}"
                                     f" {cum}")
                    cum += inst.counts[-1]
                    lines.append(f"{name}_bucket"
                                 f"{_fmt_labels(key, le='+Inf')} {cum}")
                    lines.append(f"{name}_sum{_fmt_labels(key)} "
                                 f"{_fmt_num(inst.sum)}")
                    lines.append(f"{name}_count{_fmt_labels(key)} {cum}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)} "
                                 f"{_fmt_num(inst.value)}")
        return "\n".join(lines) + "\n"


def _fmt_num(v: float) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


def _fmt_labels(key: _LabelKey, **extra: str) -> str:
    items = list(key) + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


def parse_prometheus(text: str) -> Dict[str, Any]:
    """Minimal reader for :meth:`MetricsRegistry.to_prometheus` output —
    enough for the round-trip schema guard.  Returns
    ``{"types": {name: kind}, "samples": {(sample_name, labelkey): value}}``
    where histogram samples keep their ``_bucket``/``_sum``/``_count``
    suffixes and the ``le`` label."""
    types: Dict[str, str] = {}
    samples: Dict[Tuple[str, _LabelKey], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        body, val = line.rsplit(None, 1)
        if "{" in body:
            name, rest = body.split("{", 1)
            rest = rest.rstrip("}")
            labels = {}
            for item in rest.split(","):
                k, v = item.split("=", 1)
                labels[k] = v.strip('"')
        else:
            name, labels = body, {}
        samples[(name, _label_key(labels))] = float(val)
    return {"types": types, "samples": samples}


class _NullInstrument:
    """Absorbs every instrument method; reads as zero/empty."""
    kind = "null"
    value = 0
    sum = 0.0
    count = 0
    counts: List[int] = []
    uppers: Tuple[float, ...] = ()
    mean = math.nan

    def inc(self, n: float = 1) -> None: pass
    def dec(self, n: float = 1) -> None: pass
    def set(self, v: float) -> None: pass
    def observe(self, v: float) -> None: pass
    def quantile(self, q: float) -> float: return math.nan


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry(MetricsRegistry):
    """Disabled registry: every getter returns a shared no-op instrument
    and snapshots are empty.  ``ServeEngine(metrics=False)`` uses this so
    the instrumented call sites stay unconditional — the on/off
    token-identity test relies on both modes running the exact same
    scheduler code."""

    enabled = False

    def counter(self, name, help="", **labels):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def gauge(self, name, help="", **labels):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def histogram(self, name, buckets, help="", **labels):  # type: ignore[override]
        return _NULL_INSTRUMENT

    def get(self, name, **labels):
        return None


NULL_REGISTRY = NullRegistry()
