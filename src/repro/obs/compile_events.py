"""Process-wide XLA compile-event accounting.

JAX emits a ``jax.monitoring`` duration event every time it actually hands
a computation to the backend compiler (``/jax/core/compile/
backend_compile_duration`` on current releases, ``..._time_sec`` on older
ones); jit-cache hits emit nothing.  This module installs ONE passive
listener for those events and exposes a monotonic counter, which is what
lets the serve engine answer "did this dispatch compile anything?" without
reaching into jit internals:

* ``ServeEngine`` brackets every hot-path dispatch with :func:`total` and
  feeds the delta into the ``serve_compile_total`` counter (phase label
  ``serve`` vs ``warmup``), so a mid-serve compile — the latency cliff the
  AOT warmup exists to eliminate — is visible in metrics the moment it
  happens;
* the swanlint Layer-2 audit and ``bench_warmup`` gate "zero new XLA
  compiles after ``warmup()``" on the same counter, which also catches
  compiles the per-family jit-cache census cannot see (eager host-side
  ops like the temperature-row gather).

The listener is a pure Python counter increment — it never touches the
arrays being compiled and adds nothing to dispatch latency.  Install is
idempotent; listeners cannot be unregistered in JAX, so the counter is
process-global and monotonic (consumers must difference it).
"""
from __future__ import annotations

_COMPILE_EVENT_PREFIX = "/jax/core/compile/backend_compile"

_total = 0
_installed = False


def _listener(event: str, duration: float, **kwargs) -> None:
    global _total
    if event.startswith(_COMPILE_EVENT_PREFIX):
        _total += 1


def install() -> None:
    """Register the compile-event listener (idempotent, process-global)."""
    global _installed
    if _installed:
        return
    import jax.monitoring
    jax.monitoring.register_event_duration_secs_listener(_listener)
    _installed = True


def total() -> int:
    """Backend compiles observed since :func:`install` (monotonic)."""
    return _total
