"""Structured JSONL event trace for the serving stack, plus host-timing
spans and a ``jax.profiler`` step hook.

Every event is one JSON object per line::

    {"event": "admit", "step": 12, "uid": "req3", "slot": 1, "shard": 0,
     "prompt_len": 44, "k": 8, "mode": "chunked"}

``step`` is the ENGINE step at emit time — the deterministic scheduler
clock every serve metric is indexed by (wall-clock timestamps would make
traces host-dependent and would tempt instrumentation into jitted code).
Wall time enters only through the explicit :func:`span` helper, which
emits a ``span`` event carrying ``wall_ms`` measured strictly on the host
around a ``with`` block.

The serve-engine event vocabulary (see docs/observability.md for the full
field schema): ``submit``, ``admit``, ``admission_hold``,
``chunk_dispatch``, ``prefill_complete``, ``first_token``, ``token``,
``decode_dispatch``, ``retire``, ``page_map``, ``page_free``,
``pool_grow``, ``pool_exhausted``, ``span``, ``profile_start``,
``profile_stop``.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional


class EventTrace:
    """JSONL event sink.  ``path`` appends one JSON line per event to a
    file (line-buffered, so a crash loses at most the current line);
    ``keep=True`` (the default when no path is given) also retains events
    in-memory on ``.events`` for tests and in-process consumers."""

    def __init__(self, path: Optional[str] = None,
                 keep: Optional[bool] = None):
        self.path = path
        self._fh = open(path, "w", buffering=1) if path else None
        self.keep = (path is None) if keep is None else keep
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: str, step: int, **fields: Any) -> None:
        rec = {"event": event, "step": int(step), **fields}
        if self.keep:
            self.events.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec, sort_keys=True) + "\n")

    def select(self, event: str, **match: Any) -> List[Dict[str, Any]]:
        """In-memory events of one type whose fields match ``match``."""
        return [e for e in self.events if e["event"] == event
                and all(e.get(k) == v for k, v in match.items())]

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "EventTrace":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @staticmethod
    def read(path: str) -> List[Dict[str, Any]]:
        """Parse a JSONL trace file back into event dicts."""
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]


@contextmanager
def span(trace: Optional[EventTrace], name: str, step: int = 0,
         **fields: Any):
    """Wall-clock host-timing span: emits a ``span`` event with
    ``wall_ms`` on exit.  ``trace=None`` is a no-op (call sites stay
    unconditional), and the clock is read strictly OUTSIDE jitted code —
    a span around an async dispatch measures host enqueue time, not
    device time; wrap ``jax.block_until_ready`` explicitly to time
    compute."""
    if trace is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        trace.emit("span", step=step, name=name,
                   wall_ms=(time.perf_counter() - t0) * 1e3, **fields)


class StepProfiler:
    """Bracket N engine steps with ``jax.profiler`` start/stop.

    The engine calls :meth:`step_start` / :meth:`step_end` around every
    scheduler step; the first ``step_start`` opens the trace, and the
    N-th ``step_end`` closes it — one profile per instance, covering
    exactly ``n_steps`` engine steps (admission + chunk dispatch + decode
    dispatch included).  View with TensorBoard or Perfetto against
    ``logdir``.  ``start``/``stop`` are injectable for tests."""

    def __init__(self, logdir: str, n_steps: int,
                 trace: Optional[EventTrace] = None,
                 start: Optional[Callable[[str], Any]] = None,
                 stop: Optional[Callable[[], Any]] = None):
        if n_steps < 1:
            raise ValueError(f"n_steps={n_steps} must be >= 1")
        self.logdir = logdir
        self.n_steps = n_steps
        self.remaining = n_steps
        self.active = False
        self.done = False
        self._trace = trace
        self._start = start
        self._stop = stop

    def step_start(self, step: int = 0) -> None:
        if self.done or self.active:
            return
        if self._start is None:
            import jax
            self._start = jax.profiler.start_trace
            self._stop = self._stop or jax.profiler.stop_trace
        self._start(self.logdir)
        self.active = True
        if self._trace is not None:
            self._trace.emit("profile_start", step=step,
                             logdir=self.logdir, n_steps=self.n_steps)

    def step_end(self, step: int = 0) -> None:
        if not self.active:
            return
        self.remaining -= 1
        if self.remaining > 0:
            return
        self._stop()
        self.active = False
        self.done = True
        if self._trace is not None:
            self._trace.emit("profile_stop", step=step, logdir=self.logdir)
