"""Training runtime: step construction (grad-accum via scan), the Trainer
loop with fault tolerance (async checkpoints, preemption handler, straggler
watchdog), and mesh-aware jit wiring.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticStream
from repro.models import get_model
from repro.optim.adamw import adamw_update, init_opt_state
from repro.runtime.fault_tolerance import PreemptionHandler, StepWatchdog
from repro.runtime.grad_compress import compress_gradients

Params = Dict[str, Any]


def make_train_step(cfg, opt_cfg, grad_accum: int = 1,
                    grad_compression: str = "none") -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1: the global batch is split into ``grad_accum`` microbatches
    scanned sequentially with f32 gradient accumulation (memory vs compute
    trade used by the 405B/398B configs).
    """
    api = get_model(cfg)

    def loss_fn(p, mb):
        return api.loss(p, cfg, mb)

    def train_step(params: Params, opt_state: Params, batch: Params):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def resh(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])
            micro = jax.tree_util.tree_map(resh, batch)

            def mb_step(acc, mb):
                g_acc, l_acc = acc
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32) / grad_accum, g_acc, g)
                return (g_acc, l_acc + l / grad_accum), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(mb_step, (g0, 0.0), micro)
            metrics = {"nll": loss, "aux": jnp.zeros(()), "z": jnp.zeros(())}
        if grad_compression == "int8":
            grads = compress_gradients(grads)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om, "loss": loss}
        return new_params, new_opt, metrics

    return train_step


class Trainer:
    """Single-controller training loop with checkpoint/restart semantics.

    Resume is bit-exact: data batches are a pure function of the step index,
    and optimizer state + params round-trip through the checkpointer
    losslessly (test-enforced in tests/test_checkpoint.py).
    """

    def __init__(self, train_cfg, stream=None, jit: bool = True,
                 in_shardings=None, donate: bool = True):
        self.cfg = train_cfg
        self.model_cfg = train_cfg.model
        self.api = get_model(self.model_cfg)
        self.stream = stream or SyntheticStream(
            self.model_cfg.vocab_size, train_cfg.global_batch,
            train_cfg.seq_len, seed=train_cfg.seed)
        step_fn = make_train_step(self.model_cfg, train_cfg.optimizer,
                                  self.model_cfg.grad_accum,
                                  train_cfg.grad_compression)
        if jit:
            kw = {"donate_argnums": (0, 1)} if donate else {}
            if in_shardings is not None:
                kw["in_shardings"] = in_shardings
            self.step_fn = jax.jit(step_fn, **kw)
        else:
            self.step_fn = step_fn
        self.ckpt = Checkpointer(train_cfg.checkpoint_dir,
                                 keep=train_cfg.keep_checkpoints)
        self.watchdog = StepWatchdog()
        self.preemption = PreemptionHandler()
        self.metrics_log: list = []

    def init_state(self) -> Tuple[Params, Params, int]:
        params = self.api.init_params(jax.random.PRNGKey(self.cfg.seed),
                                      self.model_cfg)
        opt_state = init_opt_state(params, self.cfg.optimizer)
        return params, opt_state, 0

    def restore_or_init(self) -> Tuple[Params, Params, int]:
        latest = self.ckpt.latest_step()
        params, opt_state, _ = self.init_state()
        if latest is None:
            return params, opt_state, 0
        state = self.ckpt.restore(latest, {"params": params, "opt": opt_state})
        return state["params"], state["opt"], latest

    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        params, opt_state, start = self.restore_or_init()
        total = steps if steps is not None else self.cfg.steps
        step = start
        for step in range(start, total):
            batch_np = self.stream.batch_at(step)
            batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
            t0 = time.monotonic()
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            # swanlint: disable=SWAN102 -- train loop, not the serve path:
            # the watchdog needs device-inclusive step time, so this sync
            # IS the measurement (serve engines must never do this per step)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.record(step, time.monotonic() - t0)
            if step % self.cfg.log_every == 0 or step == total - 1:
                self.metrics_log.append(
                    # swanlint: disable=SWAN102 -- log-cadence host reads of
                    # already-synced scalars (block_until_ready above), every
                    # log_every steps rather than per step
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"])})
            want_ckpt = ((step + 1) % self.cfg.checkpoint_every == 0
                         or step == total - 1)
            if self.preemption.triggered:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state},
                               blocking=True)
                break
            if want_ckpt:
                self.ckpt.save(step + 1, {"params": params, "opt": opt_state},
                               blocking=not self.cfg.async_checkpoint)
        self.ckpt.wait()
        return {"params": params, "opt": opt_state, "step": step + 1,
                "log": self.metrics_log,
                "stragglers": self.watchdog.stragglers}
