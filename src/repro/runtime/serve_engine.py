"""Continuous-batching serve engine: a shard-local slot scheduler over
mesh-sharded batched caches.

The lockstep ``ServeSession`` (one scalar ``pos`` for the whole batch)
wastes slots the moment sequences differ in length: everyone waits for the
longest prompt and the longest generation.  This engine admits and retires
sequences independently:

  * a request queue (FIFO by default; ``admission="srf"`` picks the
    shortest remaining request first, bounding TTFT when the queue exceeds
    prefill capacity) feeds ``n_slots`` cache slots;
  * one jitted decode executable advances ALL active slots per engine step
    with per-sequence positions ``pos [B]`` (free slots idle at pos = -1;
    their lanes compute masked garbage that is never read);
  * finished sequences free their slot immediately — the next queued
    request backfills it on the same engine step.

Per-request SWAN ``k`` (the paper's runtime-tunable compression) rides
along as a traced ``[B]`` operand: a batch can mix compression levels and
the decode step still compiles exactly once (see ``decode_cache_size`` —
asserted by tests/test_serve_engine.py).

Mesh sharding (``mesh=``, a Mesh with a ``data`` axis from
``repro.launch.mesh``): the ENTIRE batched serve state — dense/slab/ring
leaves, per-sequence ``pos``/``buf_pos``/``k`` operands, and the paged
pool (page axis sharded like the slab batch axis) — lives partitioned
over the mesh's data axis via per-leaf ``PartitionSpec``s from
``repro.sharding.serve_specs``.  Slots map to shards contiguously::

    slot  ->  (shard = slot // n_local,  lane = slot % n_local)

and the HOST scheduler is shard-local: admission places a request only in
a shard with a free lane (and, paged, free pages in that shard's block of
the pool — ``repro.runtime.page_pool`` keeps one free list per shard with
shard-local physical page indices and a per-shard trash page), the
budgeted round-robin prefill selection runs independently per shard, and
retirement returns pages to the owning shard's free list.  Every jitted
dispatch goes through ``sharding.api.shard_map_compat`` (jax.shard_map on
new releases, jax.experimental.shard_map at the JAX 0.4.35 floor) with
those specs, so each shard executes exactly the single-device engine's
computation on its local block — no cross-shard collectives anywhere on
the serve path — while the engine still issues exactly ONE prefill-chunk
dispatch and ONE decode dispatch per step regardless of shard count.
Model weights are replicated over the mesh by default
(``shard_params=True`` stores them sharded by ``repro.sharding.specs``
instead; they are gathered at dispatch).  The sharded engine is
token-identical to the single-device engine at any compression level
because lanes never interact (tests/test_sharded_engine.py;
benchmarks/bench_sharded_serve.py).  What remains for true multi-process
serving: per-host request routing in front of the shard-local scheduler
and a device-resident (rather than host-assembled) page table — the slot
-> (shard, lane) mapping and per-shard pools here are exactly the state a
per-process scheduler would own.

Prompt-length bucketing: prompts are padded to power-of-two buckets and the
true length rides along as a traced scalar, so prefill compiles
O(log max_seq) times instead of once per distinct prompt length.  Greedy
sampling happens on device (argmax inside the jitted decode step); the full
logits row-trip to host only when a request asks for temperature sampling.

Paged sparse cache (``paged=True``; SWAN only): instead of reserving
``[B, Kv, max_seq, k]`` sparse rows per slot, all slots share one page pool
``[n_pages, Kv, page_size, k]`` per layer side, addressed through a
host-managed page table (``repro.runtime.page_pool``).  Admission maps just
enough pages for the prompt's winnowed tokens, decode grows the mapping as
tokens land, and retirement returns pages for immediate reuse — cache
memory follows LIVE tokens, not ``n_slots * max_seq``.  Over-committed
pools hold admissions until pages free; with ``pool_grow=True`` the engine
instead GROWS the device pool (2x pages per shard, copy, extend the free
lists) up to the full-reservation cap, so admissions never wait and
mid-decode exhaustion disappears.  The paged engine is token-identical to
the slab engine (tests/test_paged_engine.py).

Chunked prefill (``prefill_chunk=C``, power of two; ``None`` = monolithic):
a monolithic admission stalls every active decode slot for the whole
prompt's prefill.  With chunking, each slot moves through a small state
machine::

    queued -> PREFILLING -> DECODING -> retired
               |  chunks of <= C tokens per engine step, via
               |  ``api.prefill_chunk`` straight into the slot's lanes of
               |  the BATCHED state (no single-slot transient at all: the
               |  slab path's init_serve_state(1, max_seq) admission
               |  allocation is gone, and paged admissions map pages per
               |  chunk, not per prompt)

Batched concurrent prefill (``prefill_slots=P``, ``prefill_budget=T``;
both PER SHARD under a mesh — each shard's lanes are its own device's
compute): up to ``P`` slots per shard may be PREFILLING at once, and every
engine step each shard round-robins its per-step token budget ``T``
(default ``P * C``) across them — a rotating pointer picks up to ``P``
in-flight prefills, each advances by one full chunk, and ALL shards'
selected chunks are packed into ONE jitted multi-slot executable
(``transformer.lm_prefill_chunk_batched``, traced ``[P]``
slot/start/true_len/k operands; under a mesh the lane axis is laid out
``[dp, P_local]`` so each shard's block only ever touches its own slots).
The per-shard lane count is bucketed to a power of two (dead lanes park
their slot index out of the SHARD'S range: slab/ring writes drop, paged
writes land on the shard's trash page), so an admission burst compiles
O(log n_slots × log chunk) executables instead of one per combination of
in-flight prefills — and each engine step issues exactly ONE chunk
dispatch plus ONE decode dispatch no matter how many prefills are in
flight or how many shards the mesh has.  Under a burst of admissions,
time-to-first-token is therefore O(prompt chunks), not O(queue depth ×
prompt chunks), and the round-robin keeps every in-flight prefill
advancing (no starvation) — benchmarks/bench_concurrent_prefill.py gates
the p99 TTFT win.

PREFILLING slots sit at ``pos = -1``; the decode step treats ``pos < 0``
lanes as dead (ring untouched, sparse/dense writes dropped or sent to the
trash page), which is what makes mid-prefill interleaving safe.  The last
chunk's logits seed the first sampled token and the slot flips to
DECODING.  Chunk boundaries are invisible in the cache, and per-lane chunk
boundaries never depend on the schedule — so chunked == monolithic,
batched-concurrent == serial, and sharded == single-device, token for
token, at any compression level (tests/test_chunked_prefill.py,
tests/test_concurrent_prefill.py, tests/test_sharded_engine.py).

Observability (``repro.obs``): the engine carries a ``metrics`` registry
(``metrics=False`` swaps in a no-op registry) and an optional ``trace``
JSONL event sink.  ALL instrumentation lives on the host side of the
dispatch boundaries — counters/gauges/histograms are plain Python updates
between jitted calls, trace events are step-indexed (never wall-clocked),
and nothing observable is threaded into a traced function — so metrics on
vs off produces identical tokens, identical dispatch counts and identical
compiled executables (pinned by tests/test_obs_engine.py).  Wall time
appears only in explicit ``obs.span`` blocks and the ``profile_steps``
hook that brackets N engine steps with ``jax.profiler`` start/stop.

Byte accounting — ``cache_report()`` and the ``kv_cache_*`` gauges read
the SAME ``_cache_bytes()`` source (slab, paged and sharded paths share
it; per-shard entries always sum exactly to the totals).  The three
numbers mean:

  * ``reserved_bytes`` — bytes physically allocated on the device for
    cache state right now: the full slab/dense layout for slab engines
    (committed at init, so reserved == live there), or every pool page a
    paged engine has allocated (free pages included — the pool grows but
    never shrinks) plus the per-slot ring/dense buffers and the shipped
    table prefix.
  * ``live_bytes`` — bytes addressable by LIVE tokens right now: pages
    actually mapped to admitted sequences (paged), or the whole slab
    (slab engines address every row by construction).  This is the
    number that tracks generated tokens and drops on retirement.
  * ``page_table_shipped_bytes`` — bytes of the page-table PREFIX the
    next decode dispatch ships to the device ([n_slots, bucket] int32,
    bucketed over DECODING slots' mapped pages).  The host-resident full
    table is scheduler state, not device memory; only this prefix rides
    along on dispatches.

Executable warmup (``warmup()``; ``repro.runtime.warmup``): every shape
the scheduler can legally request is enumerable from static config —
decode page buckets, the chunk ``(prefix, P, C)`` matrix, prefill/insert
pads, the eager sampling/fetch one-offs.  ``warmup()`` dummy-dispatches
that whole family through the same jitted callables ``step()`` uses
(dead-lane operands, donation threaded through), so post-warmup traffic
triggers ZERO new XLA compiles (``executable_census()`` + the
process-global ``repro.obs.compile_events`` listener; machine-checked by
the swanlint Layer-2 ``warmup_checks`` and ``bench_warmup``).  With
``async_fetch=True`` the decode token transfer starts asynchronously at
dispatch and resolves at the top of the NEXT step, overlapping the copy
with host scheduling — token-, step- and dispatch-identical to the sync
path.  See docs/serving.md.
"""
from __future__ import annotations

import inspect
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hybrid_cache as hc
from repro.core import paged_cache as pc
from repro.kernels.dispatch import (pallas_decode_supported,
                                    resolve_interpret, resolve_use_pallas)
from repro.models import get_model, swan_applicable
from repro.obs import compile_events
from repro.obs.metrics import MetricsRegistry, NULL_REGISTRY
from repro.obs.trace import EventTrace, StepProfiler
from repro.runtime.page_pool import PagePool, PagePoolExhausted
from repro.runtime.sampling import sample_token
from repro.runtime.serve_loop import serve_cache_report
from repro.sharding.api import shard_map_compat
from repro.sharding.serve_specs import sanitize_tree, serve_state_pspecs
from repro.sharding.specs import dp_axes, params_pspecs

Params = Dict[str, Any]

# fixed histogram buckets, in ENGINE STEPS (deterministic scheduler time —
# wall-clock never enters the registry); powers of two to match the
# engine's bucketing story
TTFT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
GAP_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)
REQ_STEP_BUCKETS = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
# host-side dispatch submit latency in ms, measured WITHOUT a device sync
# (async dispatch returns immediately once the executable is enqueued, so
# this captures launch/retrace overhead, not device compute — wall-clock
# step time lives in serve_loop's serve_step_ms); the kernel label says
# which implementation backed the hot-path read
DISPATCH_MS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                       50.0, 100.0, 250.0, 1000.0, 4000.0)


@dataclass
class Request:
    """One generation request.

    ``k``: optional per-request SWAN retention override (<= swan.k_max) —
    the runtime compression knob, tunable per request without recompiling.
    ``arrival_step``: engine step at which the request becomes visible
    (deterministic trace replay; 0 = already waiting).
    """
    uid: Any
    tokens: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos: Optional[int] = None
    k: Optional[int] = None
    arrival_step: int = 0


@dataclass
class Completion:
    uid: Any
    tokens: List[int]
    prompt_len: int
    k: Optional[int]
    admitted_step: int
    finished_step: int
    # engine step that sampled the request's FIRST token (prefill
    # completion) — time-to-first-token in scheduler steps; what the
    # concurrent-prefill benchmark gates
    first_token_step: int = -1


class _PendingTokens:
    """An in-flight device->host token fetch.

    Created at the decode/chunk dispatch site: the tiny greedy ``[N]`` id
    vector and (only when temperature lanes exist) a power-of-two-bucketed
    gather of their logits rows start their host copies IMMEDIATELY via
    ``copy_to_host_async`` — so the transfer overlaps whatever host-side
    scheduling work runs next — and ``ServeEngine._resolve_tokens`` is the
    single designed point where the host finally blocks on the values.
    ``step`` pins the engine step that DISPATCHED the fetch, so deferred
    resolution (``async_fetch=True``) stamps completions, TTFT histograms
    and trace events with the same step the synchronous path would.
    """

    __slots__ = ("greedy", "rows", "temp", "picks", "step", "lanes")

    def __init__(self, greedy, rows, temp, picks, step, lanes):
        self.greedy = greedy      # device [N] int32 (argmax ids)
        self.rows = rows          # device [pow2(n_temp), V] or None
        self.temp = temp          # lane ids of temperature picks, in order
        self.picks = picks        # [(lane, Request, draw_index)]
        self.step = step          # engine step of the dispatch
        self.lanes = lanes        # slot ids (decode) — None for chunk


@dataclass
class _Slot:
    """Slot state machine: ``prefilling`` (chunked admission in flight;
    ``n_prefilled`` prompt tokens are in the cache, lane pos = -1 keeps the
    slot out of decode) -> ``decoding`` (normal per-step decode) ->
    retired (slot freed).  Monolithic admissions enter at ``decoding``."""
    req: Request
    generated: List[int] = field(default_factory=list)
    admitted_step: int = 0
    state: str = "decoding"
    n_prefilled: int = 0
    first_token_step: int = -1
    # engine step of the most recent sampled token — inter-token step-gap
    # accounting only (never consulted by the scheduler)
    last_token_step: int = -1


class ServeEngine:
    """Continuous-batching generation over a slot-based batched cache,
    optionally sharded over a device mesh's ``data`` axis."""

    def __init__(self, cfg, params, swan=None, projections=None,
                 max_seq: int = 4096, n_slots: int = 4, jit: bool = True,
                 paged: bool = False, page_size: int = 64,
                 n_pages: Optional[int] = None, bucket_prompts: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefill_slots: int = 1,
                 prefill_budget: Optional[int] = None,
                 mesh=None, shard_params: bool = False,
                 pool_grow: bool = False, admission: str = "fifo",
                 metrics=True, trace: Optional[EventTrace] = None,
                 use_pallas: Optional[bool] = None,
                 async_fetch: bool = False):
        self.cfg = cfg
        # passive process-global compile counting (repro.obs.compile_events)
        # — lets dispatch sites report mid-serve compiles into metrics and
        # lets warmup/audit gate "zero compiles after warmup()"
        compile_events.install()
        # observability sink: a shared registry may be passed in; False
        # swaps in the no-op registry (the call sites stay unconditional,
        # which is what lets tests prove on == off token-for-token)
        if isinstance(metrics, MetricsRegistry):
            self.metrics = metrics
        else:
            self.metrics = MetricsRegistry() if metrics else NULL_REGISTRY
        self.trace = trace
        self._profiler: Optional[StepProfiler] = None
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "encoder-decoder serving needs per-request encoder frames; "
                "use the lockstep ServeSession for whisper-style models")
        self.api = get_model(cfg)
        self.swan = swan if (swan and swan.enabled and swan_applicable(cfg)) else None
        self.projections = projections
        self.max_seq = max_seq
        self.n_slots = n_slots
        if self.swan is not None:
            self.swan.validate(cfg.d_head)
            if projections is None:
                raise ValueError("SWAN enabled but no projections given — "
                                 "run calibrate_swan first")
        if admission not in ("fifo", "srf"):
            raise ValueError(f"admission={admission!r}: 'fifo' or 'srf'")
        self.admission = admission
        self.pool_grow = pool_grow
        self._jit = jit

        # --- mesh topology: slot -> (shard, lane) ----------------------
        self.mesh = mesh
        if mesh is not None:
            if "data" not in mesh.axis_names:
                raise ValueError("serve mesh needs a 'data' axis — build it "
                                 "with repro.launch.mesh.make_serve_mesh")
            self._dpx = dp_axes(mesh)
            self.dp = int(np.prod([mesh.shape[a] for a in self._dpx]))
        else:
            self._dpx = None
            self.dp = 1
        if self.dp < 1 or n_slots % self.dp:
            raise ValueError(f"n_slots={n_slots} not divisible by the "
                             f"mesh's data-parallel degree {self.dp}")
        self.n_local = n_slots // self.dp
        if shard_params and (mesh is None or "model" not in mesh.axis_names):
            raise ValueError("shard_params needs a mesh with a 'model' axis")
        self.params = params

        prefill_sig = inspect.signature(self.api.prefill).parameters
        decode_sig = inspect.signature(self.api.decode_step).parameters
        # per-request k needs the family to thread k_active through
        # prefill/decode (transformer families: dense/moe/vlm; jamba/ssm
        # serve with their fixed config-level k)
        self._k_threading = (
            self.swan is not None
            and "k_active" in prefill_sig and "k_active" in decode_sig)
        # prompt bucketing needs true_len-aware prefill (transformer
        # families; recurrent state would absorb the padding junk)
        self._bucketing = bucket_prompts and "true_len" in prefill_sig
        # Pallas fast path: the kernel-backed decode/chunk read replaces
        # the pure-JAX gather when the family threads the flag AND the
        # cache shape is kernel-eligible (topk + ring buffer). use_pallas
        # None = auto: compiled kernels on TPU, off elsewhere (interpret
        # mode stays available for tests by forcing use_pallas=True on
        # CPU, where the kernels run under the Pallas interpreter).
        pallas_ok = ("use_pallas" in decode_sig
                     and pallas_decode_supported(self.swan))
        self.use_pallas = resolve_use_pallas(use_pallas) and pallas_ok
        if use_pallas and not pallas_ok:
            raise ValueError(
                "use_pallas=True but the model family or SWAN config has "
                "no kernel path (needs transformer-family decode with "
                "mode='topk' and buffer > 0)")
        self._pallas_interpret = resolve_interpret(None)
        k_fill = 0 if self.swan is None else self.swan.k_max

        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None:
            if prefill_chunk < 1 or prefill_chunk & (prefill_chunk - 1):
                raise ValueError(f"prefill_chunk={prefill_chunk} must be a "
                                 "power of two")
            if max_seq % prefill_chunk:
                raise ValueError(f"max_seq={max_seq} not divisible by "
                                 f"prefill_chunk={prefill_chunk}")
            if self.api.prefill_chunk is None:
                raise ValueError(f"{cfg.family!r} family cannot resume a "
                                 "prefill mid-prompt (recurrent state) — "
                                 "chunked prefill unsupported")
        if prefill_slots < 1:
            raise ValueError(f"prefill_slots={prefill_slots} must be >= 1")
        if prefill_slots > 1 and prefill_chunk is None:
            raise ValueError("prefill_slots > 1 (batched concurrent "
                             "prefill) requires prefill_chunk")
        # per-shard: each shard's selected lanes form its own block of the
        # packed chunk dispatch
        self.prefill_slots = min(prefill_slots, self.n_local)
        # soft per-step token cap round-robined across in-flight prefills
        # (per shard): lanes are selected until the budget is spent, and
        # every selected lane still advances a FULL chunk — boundaries
        # never depend on the budget, which is what keeps the batched
        # scheduler token-identical to the serial one at any compression
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(f"prefill_budget={prefill_budget} must be >= 1")
        if prefill_budget is not None and prefill_chunk is None:
            raise ValueError("prefill_budget requires prefill_chunk — a "
                             "monolithic admission has no per-step budget")
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else self.prefill_slots * (prefill_chunk or 0))

        self.paged = paged
        if paged:
            if self.swan is None:
                raise ValueError("paged=True requires SWAN: only the sparse "
                                 "sides have a paged layout")
            if (self.api.init_paged_state is None
                    or "page_tab" not in decode_sig):
                raise ValueError(f"{cfg.family!r} family has no paged cache")
            if max_seq % page_size:
                raise ValueError(f"max_seq={max_seq} not divisible by "
                                 f"page_size={page_size}")
            max_pages = max_seq // page_size
            # default pool: full per-shard reservation (+1 trash page per
            # shard) rounded up to a multiple of 8 pages per shard (extra
            # pages are plain free capacity) — operators shrink n_pages to
            # over-commit; live accounting still tracks tokens, and
            # admission waits for pages (or grows the pool, pool_grow)
            # instead of failing
            if n_pages is None:
                n_pages = self.dp * (
                    -(-(self.n_local * max_pages + 1) // 8) * 8)
            elif n_pages % self.dp:
                raise ValueError(f"n_pages={n_pages} not divisible by the "
                                 f"mesh's data-parallel degree {self.dp}")
            self.pool: Optional[PagePool] = PagePool(
                n_pages, max_pages, n_slots, page_size, n_shards=self.dp)
            self.pool.bind_obs(self.metrics, trace,
                               step_fn=lambda: self.step_count)
            self.state = self.api.init_paged_state(
                cfg, self.swan, n_slots, max_seq, n_pages, page_size)
        else:
            self.pool = None
            self.state = self.api.init_serve_state(cfg, self.swan, n_slots,
                                                   max_seq)
        sw, pj = self.swan, self.projections

        # --- mesh placement -------------------------------------------
        if mesh is not None:
            # data-parallel compute ONLY: the serve dispatch bodies are
            # lane-local (no split-S stat merge), so strip every non-dp
            # axis from the production serve-state specs — on a mesh that
            # also carries 'model', cache sequence dims must stay
            # replicated across it (sharding them without collectives in
            # the shard_map body would silently corrupt the softmax)
            keep = set(self._dpx)

            def _dp_only(spec):
                return P(*[ax if (ax in keep
                                  or (isinstance(ax, tuple)
                                      and set(ax) <= keep)) else None
                           for ax in tuple(spec)])

            self._state_specs = jax.tree_util.tree_map(
                _dp_only, serve_state_pspecs(self.state, mesh),
                is_leaf=lambda x: isinstance(x, P))
            self.state = jax.device_put(
                self.state, jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), self._state_specs))
            if shard_params:
                p_specs = sanitize_tree(params_pspecs(params, cfg, mesh),
                                        params, mesh)
                self.params = jax.device_put(
                    params, jax.tree_util.tree_map(
                        lambda s: NamedSharding(mesh, s), p_specs))
            else:
                self.params = jax.device_put(params, NamedSharding(mesh, P()))
        else:
            self._state_specs = None

        def prefill_fn(p, batch_in, state, k_act, true_len):
            kw = {}
            if self._k_threading:
                kw["k_active"] = k_act
            if self._bucketing:
                kw["true_len"] = true_len
            return self.api.prefill(p, cfg, batch_in, state, sw, pj, **kw)

        def decode_fn(p, token, pos, k_act, page_tab, state):
            kw = {}
            if self._k_threading:
                kw["k_active"] = k_act
            if self.paged:
                kw["page_tab"] = page_tab
            if self.use_pallas:
                kw["use_pallas"] = True
                kw["pallas_interpret"] = self._pallas_interpret
            logits, state = self.api.decode_step(p, cfg, token, pos, state,
                                                 sw, pj, **kw)
            # device-side greedy sampling: ship back [B] token ids, not
            # [B, V] logits (host fetches logits only for temperature > 0)
            return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32), state

        n_local = self.n_local

        def local_slot(slot):
            """Global slot index -> this shard's local lane (parked at the
            out-of-range value ``n_local`` off-shard, so scatters with
            mode="drop" write nothing there — NEVER left negative, which
            jnp index ops would wrap)."""
            if mesh is None:
                return slot
            ls = slot - _dp_index(mesh, self._dpx) * n_local
            return jnp.where((ls >= 0) & (ls < n_local), ls, n_local)

        def insert_fn(big, one, slot):
            ls = local_slot(slot)
            return jax.tree_util.tree_map(
                lambda b, o: b.at[:, ls].set(o[:, 0].astype(b.dtype),
                                             mode="drop"), big, one)

        def insert_paged_fn(big, one, slot, phys_rows):
            ls = local_slot(slot)
            if mesh is not None:
                phys_rows = jnp.where(ls < n_local, phys_rows, pc.TRASH_PAGE)
            return pc.paged_insert_prefill(big, one, ls, phys_rows,
                                           page_size)

        def make_chunk_fn(prefix_len):
            def chunk_fn(p, tokens, state, slot, start, k_act, true_len,
                         page_tab):
                kw = {}
                if self._k_threading:
                    kw["k_active"] = k_act
                if self.paged:
                    kw["page_tab"] = page_tab
                if self.use_pallas:
                    kw["use_pallas"] = True
                    kw["pallas_interpret"] = self._pallas_interpret
                logits, state = self.api.prefill_chunk(
                    p, cfg, {"tokens": tokens}, state, slot, start, sw, pj,
                    true_len=true_len, prefix_len=prefix_len, **kw)
                # device-side greedy first-token sampling, mirroring
                # decode_fn: ship back [P] ids; logits rows cross to host
                # only for lanes that finished a temperature request's
                # prompt
                return (logits,
                        jnp.argmax(logits, axis=-1).astype(jnp.int32), state)
            return chunk_fn

        self._make_chunk_fn = make_chunk_fn
        # one jitted chunk executable family per STATIC slab/dense read
        # prefix bucket (None for paged — its read window is the shipped
        # page-table prefix); each family still retraces per (P, C, table
        # width) shape bucket exactly as static_argnums would
        self._chunk_fns: Dict[Optional[int], Any] = {}

        if mesh is not None:
            dpx = self._dpx
            rep, lane, lane2 = P(), P(dpx), P(dpx, None)
            st = self._state_specs
            tab = lane2 if paged else rep
            self._decode_specs = ((rep, lane, lane, lane, tab, st),
                                  (lane2, lane, st))
            self._chunk_specs = ((rep, lane2, st, lane, lane, lane, lane,
                                  tab), (lane2, lane, st))
            # monolithic admission: the batch=1 prefill is replicated
            # compute (every shard runs it; only the owner's insert lands)
            prefill_fn = shard_map_compat(prefill_fn, mesh,
                                          (rep, rep, rep, rep, rep),
                                          (rep, rep))
            decode_fn = shard_map_compat(decode_fn, mesh,
                                         *self._decode_specs)
            insert_fn = shard_map_compat(insert_fn, mesh, (st, rep, rep), st)
            insert_paged_fn = shard_map_compat(insert_paged_fn, mesh,
                                               (st, rep, rep, rep), st)
        if jit:
            self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
            self._decode = jax.jit(decode_fn, donate_argnums=(5,))
            self._insert = jax.jit(insert_fn, donate_argnums=(0,))
            self._insert_paged = jax.jit(insert_paged_fn, donate_argnums=(0,))
        else:
            self._prefill, self._decode = prefill_fn, decode_fn
            self._insert, self._insert_paged = insert_fn, insert_paged_fn

        # overlapped host/device step: defer the decode token fetch so all
        # host scheduling work of the NEXT step (admission, chunk packing,
        # table upload) runs while the copy is in flight — token-identical
        # to the synchronous path (tests/test_warmup.py)
        self.async_fetch = bool(async_fetch)
        self._pending: Optional[_PendingTokens] = None
        # jitted pool-grow executables keyed by the page delta, so repeated
        # grows of the same size reuse one compile (and land in the census)
        self._grow_fns: Dict[int, Any] = {}
        # set by warmup(): the executable family has been pre-compiled;
        # pool growth re-warms because it reshapes every state-carrying
        # executable's operands
        self._warmed = False
        self.warmup_report: Optional[Dict[str, Any]] = None

        self.queue: deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.slot_pos = np.full((n_slots,), -1, np.int32)   # next decode position
        self.slot_k = np.full((n_slots,), k_fill, np.int32)
        self._k_fill = k_fill
        self.next_tok = np.zeros((n_slots,), np.int32)
        self.step_count = 0
        self.completions: List[Completion] = []
        # per-shard round-robin pointers over prefill lanes
        self._prefill_rr = [s * self.n_local for s in range(self.dp)]
        # device copies of page-table prefixes, keyed by shipped width and
        # invalidated by the pool's dirty counter — decode steps and chunk
        # dispatches between page-mapping events reuse the last upload
        self._table_cache: Dict[int, Any] = {}
        # jitted-call counters per engine lifetime: the sharded-serve
        # benchmark gates that per-step dispatch count is independent of
        # shard count (one chunk + one decode dispatch per step)
        self.dispatches = {"prefill": 0, "chunk": 0, "decode": 0}

    def _obs_dispatch(self, kind: str, dt: float, compiles: int = 0) -> None:
        """Record one hot-path dispatch: which kernel implementation backed
        it (pallas vs xla), the host-side submit latency, and any XLA
        compiles the dispatch triggered (``compiles`` is the
        ``compile_events.total()`` delta bracketing the call — zero in
        steady state, and zero from the very first request once
        :meth:`warmup` has run).  No device sync happens here — ``dt``
        brackets only the async dispatch call."""
        kernel = "pallas" if self.use_pallas else "xla"
        if self.use_pallas:
            self.metrics.counter(
                "serve_pallas_dispatch_total",
                "hot-path dispatches backed by the Pallas kernels",
                kind=kind).inc()
        self.metrics.histogram(
            "serve_dispatch_ms", DISPATCH_MS_BUCKETS,
            "host-side dispatch submit latency (no device sync)",
            kind=kind, kernel=kernel).observe(dt * 1e3)
        if compiles:
            self.metrics.counter(
                "serve_compile_total",
                "XLA backend compiles by phase (warmup vs mid-serve)",
                phase="serve", kind=kind).inc(compiles)

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.tokens) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.uid}: {len(req.tokens)}+{req.max_new_tokens} "
                f"tokens exceed max_seq={self.max_seq}")
        if req.k is not None:
            if self.swan is None:
                raise ValueError(f"request {req.uid}: per-request k needs SWAN")
            if not self._k_threading:
                raise ValueError(f"{self.cfg.family!r} family does not "
                                 "support per-request k overrides")
            if req.k > self.swan.k_max:
                raise ValueError(f"request {req.uid}: k={req.k} > allocated "
                                 f"k_max={self.swan.k_max}")
        self.queue.append(req)
        self.metrics.counter("serve_requests_submitted_total",
                             "requests accepted into the queue").inc()
        if self.trace is not None:
            self.trace.emit("submit", step=self.step_count, uid=req.uid,
                            prompt_len=len(req.tokens),
                            max_new_tokens=req.max_new_tokens, k=req.k,
                            arrival_step=req.arrival_step)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return sum(r.arrival_step <= self.step_count for r in self.queue)

    @property
    def done(self) -> bool:
        # an unresolved async token fetch means tokens (and possibly
        # retirements) are still owed — one more step() resolves it
        return (not self.queue and self.n_active == 0
                and self._pending is None)

    @staticmethod
    def _jit_cache_size(fn, what: str) -> int:
        """Compiled-executable count of one jitted callable.  Raises
        instead of guessing when the jit cache is not introspectable —
        a silent ``-1`` here once left the audit's executable-count
        bounds blind."""
        size = getattr(fn, "_cache_size", None)
        if not callable(size):
            raise RuntimeError(
                f"jit cache size not introspectable for the {what} "
                "dispatch on this JAX build — the executable census "
                "(and the swanlint count bounds) cannot run")
        return size()

    def executable_census(self) -> Dict[str, Any]:
        """Compiled-executable counts for EVERY jitted dispatch family the
        engine owns: decode, monolithic prefill, the chunk family (keyed
        by its static slab read-prefix bucket; ``"paged"`` for the
        table-prefix-bounded paged family), the two admission inserts and
        the pool-grow executables (keyed by page delta).  This is the one
        counting surface — the ``decode_cache_size``/``prefill_cache_size``
        properties, :meth:`warmup` and the swanlint Layer-2 audit all read
        it, so none of them can silently go blind.  Requires ``jit=True``
        (a no-jit engine has no compiled executables to count)."""
        if not self._jit:
            raise RuntimeError("executable_census requires jit=True")
        chunk = {("paged" if p is None else str(p)):
                 self._jit_cache_size(fn, f"chunk[prefix={p}]")
                 for p, fn in self._chunk_fns.items()}
        grow = {str(extra): self._jit_cache_size(fn, f"pool_grow[{extra}]")
                for extra, fn in self._grow_fns.items()}
        census: Dict[str, Any] = {
            "decode": self._jit_cache_size(self._decode, "decode"),
            "prefill": self._jit_cache_size(self._prefill, "prefill"),
            "chunk": chunk,
            "chunk_total": sum(chunk.values()),
            "insert": self._jit_cache_size(self._insert, "insert"),
            "insert_paged": self._jit_cache_size(self._insert_paged,
                                                 "insert_paged"),
            "pool_grow": grow,
            "pool_grow_total": sum(grow.values()),
        }
        census["total"] = (census["decode"] + census["prefill"]
                           + census["chunk_total"] + census["insert"]
                           + census["insert_paged"]
                           + census["pool_grow_total"])
        return census

    @property
    def decode_cache_size(self) -> int:
        """Compiled decode executables (1 == mixed-k batches share one);
        0 for a no-jit engine."""
        if not self._jit:
            return 0
        return self.executable_census()["decode"]

    @property
    def prefill_cache_size(self) -> int:
        """Compiled prefill executables, monolithic + chunked (bucketing
        keeps the total <= O(log max_seq)); 0 for a no-jit engine."""
        if not self._jit:
            return 0
        c = self.executable_census()
        return c["prefill"] + c["chunk_total"]

    def warmup(self, max_prompt_len: Optional[int] = None) -> Dict[str, Any]:
        """Pre-compile the engine's ENTIRE executable family before the
        first request: every (prompt-chunk x lane x slab-prefix /
        page-table-prefix) bucket the scheduler can legally dispatch, plus
        the host-side fetch/sampling shapes — so no request ever eats a
        mid-serve JIT compile.  Delegates to
        :func:`repro.runtime.warmup.warmup_engine` (dead-lane no-op
        dispatches through the SAME jitted callables ``step()`` uses,
        which is what actually populates the dispatch cache — an AOT
        ``lower().compile()`` would not).  Idempotent: a second call
        compiles nothing.  Returns the warmup report (also kept on
        ``self.warmup_report``); ``max_prompt_len`` trims the slab
        read-prefix family when the operator bounds admitted prompts."""
        from repro.runtime.warmup import warmup_engine
        report = warmup_engine(self, max_prompt_len=max_prompt_len)
        self._warmed = True
        self.warmup_report = report
        return report

    def shard_of(self, slot: int) -> int:
        """Which mesh shard owns ``slot`` (0 on a single device)."""
        return slot // self.n_local

    def _sample(self, logits, req: Request, n_prev: int) -> int:
        """Host-side sampling for temperature requests (greedy lanes use
        the device argmax) — shared f32-first helper, keyed per request by
        (seed, draw index)."""
        if req.temperature <= 0.0:
            return int(np.argmax(np.asarray(logits)))
        key = jax.random.fold_in(jax.random.PRNGKey(req.seed), n_prev)
        return int(sample_token(logits, req.temperature, key))

    def _start_fetch(self, logits, greedy, picks, step: int,
                     lanes=None) -> _PendingTokens:
        """Issue the device->host token transfer WITHOUT blocking: greedy
        lanes take the device argmax ([N] ints, tiny), and ONLY the
        temperature lanes' [V] rows are gathered on device — a greedy
        batch never round-trips the full logits.  The temperature index
        vector is padded to a power-of-two width (extra rows gather lane
        0 and are ignored at resolve time) so the eager gather compiles
        O(log n_slots) shapes, all of which :meth:`warmup` pre-compiles.
        Both transfers start via ``copy_to_host_async``; the host is free
        to do scheduling work until :meth:`_resolve_tokens`."""
        temp = [lane for lane, req, _ in picks if req.temperature > 0.0]
        rows = None
        if temp:
            idx = np.zeros((self._pow2(len(temp)),), np.int32)
            idx[:len(temp)] = temp
            rows = logits[jnp.asarray(idx)]
            rows.copy_to_host_async()
        greedy.copy_to_host_async()
        return _PendingTokens(greedy=greedy, rows=rows, temp=temp,
                              picks=list(picks), step=step, lanes=lanes)

    def _resolve_tokens(self, pending: _PendingTokens) -> List[int]:
        """Block on a :class:`_PendingTokens` transfer and sample one token
        per (lane, request, draw-index) triple.  This is the engine's ONLY
        decode-token host-sync point (allowlisted for swanlint SWAN102,
        like the ``_sample`` it calls) — everything upstream of it stays
        async."""
        greedy = np.asarray(pending.greedy)
        rows = (np.asarray(pending.rows) if pending.rows is not None
                else None)
        temp = pending.temp
        return [int(greedy[lane]) if req.temperature <= 0.0
                else self._sample(rows[temp.index(lane)], req, draw)
                for lane, req, draw in pending.picks]

    def _lane_tokens(self, logits, greedy, picks) -> List[int]:
        """Synchronous fetch: start the transfer and resolve it
        immediately (chunked-prefill first tokens, and the decode path
        when ``async_fetch`` is off)."""
        return self._resolve_tokens(
            self._start_fetch(logits, greedy, picks, self.step_count))

    def _resolve_pending(self) -> None:
        """Resolve the previous step's in-flight decode fetch, if any —
        called at the TOP of :meth:`step`, before admission, so the
        scheduler observes exactly the state the synchronous path would
        have left: tokens applied, retirements done and pages freed before
        any admission decision.  Metrics/trace rows are stamped with the
        DISPATCH step (``pending.step``), keeping TTFT / inter-token /
        completion accounting identical to ``async_fetch=False``."""
        pending, self._pending = self._pending, None
        if pending is None:
            return
        t0 = time.perf_counter()
        toks = self._resolve_tokens(pending)
        self.metrics.histogram(
            "serve_token_fetch_ms", DISPATCH_MS_BUCKETS,
            "host block on the decode token transfer",
            mode="async").observe((time.perf_counter() - t0) * 1e3)
        step_now = self.step_count
        self.step_count = pending.step      # stamp at the dispatch step
        try:
            self._apply_decode_tokens(pending.lanes, toks)
        finally:
            self.step_count = step_now

    def _apply_decode_tokens(self, lanes, toks) -> None:
        """Apply one decode step's sampled tokens to the scheduler state:
        advance positions, extend transcripts, feed ``next_tok``, stamp
        per-token metrics/trace, retire finished slots.  Shared verbatim
        by the sync path (same step) and the async path (resolved at the
        top of the next step, stamped with the dispatch step)."""
        gap_hist = self.metrics.histogram(
            "serve_inter_token_steps", GAP_BUCKETS,
            "engine steps between consecutive tokens of one request")
        tok_ctr = self.metrics.counter(
            "serve_tokens_generated_total",
            "sampled tokens (first tokens included)")
        for i, tok in zip(lanes, toks):
            s = self.slots[i]
            self.slot_pos[i] += 1
            s.generated.append(tok)
            self.next_tok[i] = tok
            gap_hist.observe(self.step_count - s.last_token_step)
            s.last_token_step = self.step_count
            tok_ctr.inc()
            if self.trace is not None:
                self.trace.emit("token", step=self.step_count,
                                uid=s.req.uid, slot=i,
                                index=len(s.generated) - 1, token=tok)
            self._maybe_retire(i)

    def _bucket_len(self, plen: int) -> int:
        """Smallest power-of-two bucket holding ``plen`` (capped at
        max_seq) — prefill compiles once per bucket, not per length."""
        if not self._bucketing:
            return plen
        return min(self._pow2(plen), self.max_seq)

    def _sparse_tokens(self, pos: int) -> int:
        """Winnowed (sparse-resident) tokens at decode position ``pos``."""
        return max(pos + 1 - self.swan.buffer, 0)

    def _page_bucket(self, slots) -> int:
        """Power-of-two bucket of logical pages covering every mapping in
        ``slots`` — the shipped page-table prefix width."""
        p_used = max([1] + [int(self.pool.n_mapped[i]) for i in slots])
        return min(self._pow2(p_used), self.pool.pages_per_seq)

    def _decode_bucket(self) -> int:
        dec = [i for i, s in enumerate(self.slots)
               if s is not None and s.state == "decoding"]
        return self._page_bucket(dec)

    def page_table_shipped_bytes(self) -> int:
        """Bytes of the page-table prefix a decode step ships to the device
        right now ([n_slots, p_bucket] int32) — the device-side table
        operand, as opposed to the host-resident full table.  The bucket
        covers DECODING slots, exactly as ``step()`` computes it
        (prefilling lanes are dead in the decode; chunk dispatches ship
        their own table prefix bucketed over the selected lanes)."""
        return self.n_slots * self._decode_bucket() * 4

    def _pow2(self, n: int) -> int:
        b = 1
        while b < n:
            b <<= 1
        return b

    def _record_first_token(self, slot: int, tok: int) -> None:
        """Latency accounting for a slot's FIRST sampled token (prefill
        completion) — host-side only, after the slot already recorded
        ``first_token_step``.  TTFT is step-indexed against the request's
        arrival, matching what the concurrent-prefill benchmark gates."""
        s = self.slots[slot]
        ttft = self.step_count - s.req.arrival_step
        self.metrics.histogram(
            "serve_ttft_steps", TTFT_BUCKETS,
            "engine steps from request arrival to first token").observe(ttft)
        self.metrics.counter("serve_tokens_generated_total",
                             "sampled tokens (first tokens included)").inc()
        s.last_token_step = self.step_count
        if self.trace is not None:
            self.trace.emit("prefill_complete", step=self.step_count,
                            uid=s.req.uid, slot=slot,
                            prompt_len=len(s.req.tokens))
            self.trace.emit("first_token", step=self.step_count,
                            uid=s.req.uid, slot=slot, token=tok,
                            ttft_steps=ttft)
            self.trace.emit("token", step=self.step_count, uid=s.req.uid,
                            slot=slot, index=0, token=tok)

    def _admit(self, req: Request, slot: int) -> None:
        k_req = self.swan.k_max if (self.swan and req.k is None) else (req.k or 0)
        mode = "chunked" if self.prefill_chunk is not None else "monolithic"
        self.metrics.counter("serve_admissions_total",
                             "requests admitted into a slot",
                             mode=mode).inc()
        if self.trace is not None:
            self.trace.emit("admit", step=self.step_count, uid=req.uid,
                            slot=slot, shard=self.shard_of(slot),
                            prompt_len=len(req.tokens), k=req.k, mode=mode)
        if self.prefill_chunk is not None:
            # chunked admission: just claim the slot — chunks land as the
            # round-robin budget reaches this lane (see _advance_prefills),
            # straight into the slot's lanes of the batched state.  No
            # single-slot transient at all.
            if self.paged:
                # pages are MAPPED per chunk, but the prompt's whole winnow
                # need is HELD now — the admission gate checked it against
                # the shard's free pages, and without the hold a decoding
                # slot's growth could starve this in-flight prefill
                # mid-chunking
                self.pool.reserve(slot, self.pool.pages_for(
                    self._sparse_tokens(len(req.tokens) - 1)))
            self.slots[slot] = _Slot(req=req, admitted_step=self.step_count,
                                     state="prefilling")
            self.slot_pos[slot] = -1        # dead lane until prefill done
            self.slot_k[slot] = k_req
            return
        plen = len(req.tokens)
        pad_len = self._bucket_len(plen)
        if self.paged:
            # admission transients follow the PROMPT, not max_seq: the
            # single-slot prefill state is sized to the prompt bucket
            # (rounded to whole pages), and only that page prefix is
            # scattered into the pool
            ps = self.pool.page_size
            s1 = -(-pad_len // ps) * ps
        else:
            s1 = self.max_seq      # slab insert needs shape-matched slices
        state1 = self.api.init_serve_state(self.cfg, self.swan, 1, s1)
        toks = np.zeros((pad_len,), np.int32)
        toks[:plen] = np.asarray(req.tokens, np.int32)
        c0 = compile_events.total()
        logits, state1 = self._prefill(self.params, {"tokens": toks[None]},
                                       state1, np.int32(k_req),
                                       np.int32(plen))
        dc = compile_events.total() - c0
        if dc:
            self.metrics.counter(
                "serve_compile_total",
                "XLA backend compiles by phase (warmup vs mid-serve)",
                phase="serve", kind="prefill").inc(dc)
        self.dispatches["prefill"] += 1
        self.metrics.counter("serve_dispatches_total",
                             "jitted dispatches by kind",
                             kind="prefill").inc()
        self.metrics.counter("serve_prefill_tokens_total",
                             "prompt tokens prefilled").inc(plen)
        if self.paged:
            self._ensure_pages(slot, self._sparse_tokens(plen - 1))
            self.state = self._insert_paged(
                self.state, state1, np.int32(slot),
                self.pool.table[slot, :s1 // ps])
        else:
            self.state = self._insert(self.state, state1, np.int32(slot))
        s = _Slot(req=req, admitted_step=self.step_count)
        first = self._sample(logits[0, -1], req, 0)
        s.generated.append(first)
        s.first_token_step = self.step_count
        self.slots[slot] = s
        self.slot_pos[slot] = plen
        self.slot_k[slot] = k_req
        self.next_tok[slot] = first
        self._record_first_token(slot, first)
        self._maybe_retire(slot)

    def _maybe_retire(self, slot: int) -> None:
        s = self.slots[slot]
        done = (len(s.generated) >= s.req.max_new_tokens
                or (s.req.eos is not None and s.generated[-1] == s.req.eos)
                or self.slot_pos[slot] >= self.max_seq)
        if not done:
            return
        reason = ("eos" if s.req.eos is not None
                  and s.generated[-1] == s.req.eos
                  else "max_tokens" if len(s.generated) >= s.req.max_new_tokens
                  else "max_seq")
        self.metrics.counter("serve_completions_total",
                             "retired requests by reason",
                             reason=reason).inc()
        self.metrics.histogram(
            "serve_request_steps", REQ_STEP_BUCKETS,
            "engine steps from admission to retirement").observe(
                self.step_count - s.admitted_step)
        if self.trace is not None:
            self.trace.emit("retire", step=self.step_count, uid=s.req.uid,
                            slot=slot, shard=self.shard_of(slot),
                            n_tokens=len(s.generated), reason=reason,
                            admitted_step=s.admitted_step,
                            first_token_step=s.first_token_step)
        self.completions.append(Completion(
            uid=s.req.uid, tokens=list(s.generated),
            prompt_len=len(s.req.tokens), k=s.req.k,
            admitted_step=s.admitted_step, finished_step=self.step_count,
            first_token_step=s.first_token_step))
        self.slots[slot] = None
        self.slot_pos[slot] = -1
        self.slot_k[slot] = self.swan.k_max if self.swan else 0
        self.next_tok[slot] = 0
        if self.paged:
            # pages return to the owning shard's free list NOW — a request
            # backfilled into this slot on the same engine step reuses them
            self.pool.free_slot(slot)

    def _next_request(self) -> Optional[Request]:
        """Admission policy over ARRIVED requests: FIFO takes the oldest;
        ``srf`` (shortest-remaining-first) takes the smallest total work
        (prompt + generation budget), FIFO-tiebroken, which bounds TTFT for
        short requests when the queue exceeds prefill capacity."""
        avail = [r for r in self.queue if r.arrival_step <= self.step_count]
        if not avail:
            return None
        if self.admission == "srf":
            return min(avail, key=lambda r: len(r.tokens) + r.max_new_tokens)
        return avail[0]

    def _admit_pending(self) -> None:
        while self.n_active < self.n_slots:
            nxt = self._next_request()
            if nxt is None:
                return
            free = [i for i, s in enumerate(self.slots) if s is None]
            if self.paged:
                # a request whose LIFETIME need exceeds a whole pool shard
                # can never run — grow the pool (pool_grow) or fail fast
                # instead of waiting forever
                lifetime = self.pool.pages_for(self._sparse_tokens(
                    len(nxt.tokens) + nxt.max_new_tokens - 1))
                if lifetime > self.pool.pages_per_shard - 1:
                    if not self.pool_grow:
                        raise PagePoolExhausted(
                            f"request {nxt.uid} needs {lifetime} pages over "
                            "its lifetime; each pool shard holds "
                            f"{self.pool.pages_per_shard - 1}")
                    self._grow_pool(lifetime + 1)
                # over-committed pool: admit only into a shard with enough
                # free pages for this prompt; otherwise grow (pool_grow) or
                # hold admissions until retirements free pages (FIFO
                # head-of-line on the policy's next pick)
                need = self.pool.pages_for(
                    self._sparse_tokens(len(nxt.tokens) - 1))
                fits = [i for i in free if need <=
                        self.pool.shard_free_pages(self.shard_of(i))]
                if not fits and self.pool_grow:
                    self._grow_pool(self.pool.pages_per_shard + max(need, 1))
                    fits = [i for i in free if need <=
                            self.pool.shard_free_pages(self.shard_of(i))]
                if not fits:
                    # held admission: counted per engine step spent waiting
                    self.metrics.counter(
                        "serve_admission_holds_total",
                        "steps an arrived request waited on pool pages").inc()
                    if self.trace is not None:
                        self.trace.emit("admission_hold",
                                        step=self.step_count, uid=nxt.uid,
                                        need_pages=need)
                    return
                slot = fits[0]
            else:
                slot = free[0]
            self.queue.remove(nxt)
            self._admit(nxt, slot)

    # ------------------------------------------------------------------
    # Paged-pool elasticity
    # ------------------------------------------------------------------

    def _ensure_pages(self, slot: int, n_tokens: int) -> None:
        """``pool.ensure`` with elasticity: when the pool is over-committed
        past live capacity, either grow it (``pool_grow``) or surface
        ``PagePoolExhausted`` to the caller."""
        if not self.pool_grow:
            self.pool.ensure(slot, n_tokens)
            return
        try:
            self.pool.ensure(slot, n_tokens)
        except PagePoolExhausted:
            self._grow_pool(self.pool.pages_per_shard
                            + self.pool.pages_for(n_tokens))
            self.pool.ensure(slot, n_tokens)

    def _grow_pool(self, min_pages_per_shard: int) -> None:
        """Grow the device pool: allocate at least ``min_pages_per_shard``
        (typically 2x) pages PER SHARD, copy the old pages over, extend the
        host free lists, and keep every page-table entry valid (local
        indices don't move; new pages append at the end of each shard's
        block).  Capped at the full-reservation size — at the cap a free
        slot can always admit and ``ensure`` can always succeed, so growth
        makes over-commit waits and mid-decode exhaustion impossible."""
        cap = self.n_local * self.pool.pages_per_seq + 1
        new_per = min(max(2 * self.pool.pages_per_shard,
                          min_pages_per_shard), cap)
        if new_per <= self.pool.pages_per_shard:
            raise PagePoolExhausted(
                f"page pool already at full reservation "
                f"({self.pool.pages_per_shard} pages/shard) — cannot grow")
        extra = new_per - self.pool.pages_per_shard

        # grow executables are cached per page DELTA (jit retraces per
        # input shape within one callable), so repeated growth by the
        # same stride recompiles nothing and the executable_census can
        # count the family
        fn = self._grow_fns.get(extra)
        if fn is None:
            def pad_pool(pool, _extra=extra):
                return jax.tree_util.tree_map(
                    lambda x: jnp.concatenate(
                        [x, jnp.zeros(x.shape[:1] + (_extra,) + x.shape[2:],
                                      x.dtype)], axis=1), pool)
            fn = pad_pool
            if self.mesh is not None:
                specs = self._state_specs["pool"]
                fn = shard_map_compat(fn, self.mesh, (specs,), specs)
            if self._jit:
                fn = jax.jit(fn, donate_argnums=(0,))
            self._grow_fns[extra] = fn
        state = dict(self.state)
        state["pool"] = fn(self.state["pool"])
        self.state = state
        old_per = self.pool.pages_per_shard
        self.pool.grow(new_per)
        self.metrics.counter("page_pool_grows_total",
                             "device pool growth events").inc()
        if self.trace is not None:
            self.trace.emit("pool_grow", step=self.step_count,
                            pages_per_shard_old=old_per,
                            pages_per_shard_new=new_per)
        if self._warmed:
            # the pool leaf changed shape, so every state-keyed executable
            # (decode, chunk family, grow) just went stale — re-warm now
            # and take the compiles as one visible warmup event instead of
            # scattered mid-serve cliffs on the next few dispatches
            self.warmup()

    # ------------------------------------------------------------------
    # Engine step
    # ------------------------------------------------------------------

    def _device_table(self, width: int):
        """Device copy of the page table's first ``width`` columns
        ([n_slots, width] int32, batch-sharded over the mesh's data axis —
        each shard sees its own slots' rows with shard-local physical
        indices) — cached per width and re-uploaded only when the host
        table changed (``pool.version`` dirty counter).  Decode steps and
        chunk dispatches between page-mapping events reuse the previous
        upload instead of shipping the table every step."""
        ver = self.pool.version
        hit = self._table_cache.get(width)
        if hit is None or hit[0] != ver:
            tab = np.ascontiguousarray(self.pool.table[:, :width])
            if self.mesh is not None:
                arr = jax.device_put(
                    tab, NamedSharding(self.mesh, P(self._dpx, None)))
            else:
                arr = jnp.asarray(tab)
            hit = (ver, arr)
            self._table_cache[width] = hit
        return hit[1]

    def _select_prefills(self, shard: int):
        """Round-robin up to ``prefill_slots`` PREFILLING lanes of
        ``shard`` within its per-step token budget — a SHARD-LOCAL
        decision: each shard has its own rotating pointer, so every
        in-flight prefill keeps advancing (no starvation when more
        prefills are in flight than ``prefill_slots``); each selected lane
        advances one FULL chunk, so per-lane chunk boundaries — and
        therefore tokens — never depend on the schedule."""
        lo = shard * self.n_local
        cands = [i for i in range(lo, lo + self.n_local)
                 if self.slots[i] is not None
                 and self.slots[i].state == "prefilling"]
        if not cands:
            return []
        rr = self._prefill_rr[shard]
        order = sorted(cands, key=lambda j: (j - rr) % self.n_slots)
        sel: List[int] = []
        spent = 0
        for i in order:
            if len(sel) >= self.prefill_slots or spent >= self.prefill_budget:
                break
            s = self.slots[i]
            sel.append(i)
            spent += min(len(s.req.tokens) - s.n_prefilled, self.prefill_chunk)
        self._prefill_rr[shard] = (sel[-1] + 1) % self.n_slots
        return sel

    def _advance_prefills(self) -> None:
        """Advance every shard's round-robin-selected in-flight prefills by
        one chunk EACH, packed into ONE batched chunk dispatch.  The lane
        axis is laid out ``[dp, P_local]`` — shard ``s``'s block holds only
        its own slots (as LOCAL lane indices), which is what lets the
        dispatch shard_map over the data axis with no cross-shard traffic.
        The per-shard lane count is bucketed to a power of two (dead lanes
        park slot = n_local, out of the shard's range) and full chunks
        share one width, so admission bursts compile O(log n_slots × log
        chunk) executables (times a slab-prefix or paged-table bucket
        dimension)."""
        sels = [self._select_prefills(s) for s in range(self.dp)]
        widest = max(len(s) for s in sels)
        if widest == 0:
            return
        Pl = self._pow2(widest)
        n_lanes = self.dp * Pl
        lens: Dict[int, int] = {}
        pads = []
        for sel in sels:
            for i in sel:
                s = self.slots[i]
                rem = len(s.req.tokens) - s.n_prefilled
                t = min(rem, self.prefill_chunk)
                lens[i] = t
                pads.append(self.prefill_chunk if rem >= self.prefill_chunk
                            else self._pow2(t))
        C = max(pads)
        toks = np.zeros((n_lanes, C), np.int32)
        slot_v = np.full((n_lanes,), self.n_local, np.int32)  # dead: local OOB
        start_v = np.zeros((n_lanes,), np.int32)
        tlen_v = np.ones((n_lanes,), np.int32)
        k_v = np.full((n_lanes,), self._k_fill, np.int32)
        picks = []                                  # (lane, global slot)
        for sh, sel in enumerate(sels):
            for j, i in enumerate(sel):
                lane = sh * Pl + j
                s = self.slots[i]
                st0, t = s.n_prefilled, lens[i]
                toks[lane, :t] = np.asarray(s.req.tokens[st0:st0 + t],
                                            np.int32)
                slot_v[lane] = i - sh * self.n_local
                start_v[lane] = st0
                tlen_v[lane] = t
                k_v[lane] = self.slot_k[i]
                picks.append((lane, i))
        sel_all = [i for _, i in picks]
        if self.paged:
            for lane, i in picks:
                # map pages for the tokens this chunk winnows; overshoot
                # writes past them land on the trash page and are rewritten
                # by the next chunk once its pages exist
                self._ensure_pages(i, self._sparse_tokens(
                    int(start_v[lane]) + lens[i] - 1))
            pg = self._pow2(max(1, max(int(self.pool.n_mapped[i])
                                       for i in sel_all)))
            page_tab = self._device_table(min(pg, self.pool.pages_per_seq))
            prefix = None               # the page_tab prefix bounds reads
        else:
            page_tab = np.zeros((), np.int32)           # unused operand
            prefix = min(self._pow2(int(start_v.max()) + C), self.max_seq)
        c0 = compile_events.total()
        t0 = time.perf_counter()
        logits, greedy, self.state = self._chunk_call(
            self.params, toks, self.state, slot_v, start_v, k_v, tlen_v,
            page_tab, prefix=prefix)
        self._obs_dispatch("chunk", time.perf_counter() - t0,
                           compiles=compile_events.total() - c0)
        self.dispatches["chunk"] += 1
        self.metrics.counter("serve_dispatches_total",
                             "jitted dispatches by kind", kind="chunk").inc()
        self.metrics.counter("serve_prefill_tokens_total",
                             "prompt tokens prefilled").inc(
                                 sum(lens[i] for _, i in picks))
        if self.trace is not None:
            self.trace.emit("chunk_dispatch", step=self.step_count,
                            lanes=len(picks), slots=sel_all,
                            tokens=sum(lens[i] for _, i in picks))
        fins = []
        for lane, i in picks:
            s = self.slots[i]
            s.n_prefilled += lens[i]
            if s.n_prefilled == len(s.req.tokens):      # prompt complete
                fins.append((lane, i))
        if not fins:
            return
        firsts = self._lane_tokens(
            logits, greedy, [(lane, self.slots[i].req, 0) for lane, i in fins])
        for (lane, i), first in zip(fins, firsts):
            s = self.slots[i]
            s.state = "decoding"
            s.generated.append(first)
            s.first_token_step = self.step_count
            self.slot_pos[i] = len(s.req.tokens)
            self.next_tok[i] = first
            self._record_first_token(i, first)
            self._maybe_retire(i)

    def _chunk_jit(self, prefix: Optional[int]):
        """The (possibly jitted) batched chunk executable family for a
        STATIC slab/dense read-prefix bucket (one jit per bucket — the
        moral equivalent of static_argnums, kept explicit so the mesh path
        can close the prefix into its shard_map body).  Exposed separately
        from ``_chunk_call`` so ``lower_chunk`` can AOT-lower the same
        cached callable the scheduler dispatches through."""
        fn = self._chunk_fns.get(prefix)
        if fn is None:
            fn = self._make_chunk_fn(prefix)
            if self.mesh is not None:
                fn = shard_map_compat(fn, self.mesh, *self._chunk_specs)
            if self._jit:
                fn = jax.jit(fn, donate_argnums=(2,))
            self._chunk_fns[prefix] = fn
        return fn

    def _chunk_call(self, *args, prefix: Optional[int]):
        return self._chunk_jit(prefix)(*args)

    # ------------------------------------------------------------------
    # AOT lowering (compiled-dispatch audit + warmup)
    # ------------------------------------------------------------------

    def lower_decode(self, page_bucket: Optional[int] = None):
        """AOT-lower the decode dispatch for the shapes ``step()`` would
        use right now, WITHOUT executing it: returns ``jax.stages.Lowered``
        whose ``.compile().as_text()`` is the post-optimization HLO the
        swanlint auditor scans for host transfers and stray collectives.
        ``page_bucket`` overrides the shipped page-table width (paged
        engines; ignored for slab).  Lowers the SAME jitted callable the
        scheduler dispatches through, so the audited artifact is the
        production executable, not a re-derivation."""
        if not self._jit:
            raise RuntimeError("lower_decode requires jit=True")
        i32v = jax.ShapeDtypeStruct((self.n_slots,), jnp.int32)
        if self.paged:
            width = page_bucket if page_bucket is not None \
                else self._decode_bucket()
            tab = jax.ShapeDtypeStruct((self.n_slots, width), jnp.int32)
        else:
            tab = jax.ShapeDtypeStruct((), jnp.int32)
        return self._decode.lower(self.params, i32v, i32v, i32v, tab,
                                  self.state)

    def lower_chunk(self, n_lanes: Optional[int] = None,
                    chunk: Optional[int] = None,
                    page_bucket: Optional[int] = None,
                    prefix: Optional[int] = None):
        """AOT-lower one chunked-prefill dispatch shape (defaults: one
        lane per shard, a full ``prefill_chunk`` of tokens, the smallest
        covering slab prefix / page bucket) — same contract as
        ``lower_decode``."""
        if not self._jit:
            raise RuntimeError("lower_chunk requires jit=True")
        C = chunk if chunk is not None else (self.prefill_chunk or 8)
        lanes = n_lanes if n_lanes is not None else self.dp
        i32v = jax.ShapeDtypeStruct((lanes,), jnp.int32)
        toks = jax.ShapeDtypeStruct((lanes, C), jnp.int32)
        if self.paged:
            width = page_bucket if page_bucket is not None else 1
            tab = jax.ShapeDtypeStruct((self.n_slots, width), jnp.int32)
            prefix = None               # the page_tab prefix bounds reads
        else:
            tab = jax.ShapeDtypeStruct((), jnp.int32)
            if prefix is None:
                prefix = self._bucket_len(C)
        fn = self._chunk_jit(prefix)
        return fn.lower(self.params, toks, self.state, i32v, i32v, i32v,
                        i32v, tab)

    def step(self) -> int:
        """One scheduler iteration: resolve the previous step's in-flight
        token fetch (async mode) → admit → one batched multi-slot prefill
        chunk dispatch → one batched decode dispatch → retire (or stash
        the fetch for the next step when ``async_fetch``).  Returns the
        number of sequences that finished during this call — with
        ``async_fetch`` a dispatch's completions surface one ``step()``
        call later (the tokens are identical; only the host-visible
        boundary shifts)."""
        if self._profiler is not None:
            self._profiler.step_start(self.step_count)
        n_done0 = len(self.completions)
        # the previous step's decode tokens land BEFORE any scheduling
        # decision, so admission/chunking see the same world as sync mode
        self._resolve_pending()
        self._admit_pending()
        if self.prefill_chunk is not None:
            self._advance_prefills()
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and s.state == "decoding"]
        if active:
            if self.paged:
                # grow each sequence's page mapping to cover the token its
                # decode step is about to winnow (grows the pool, or raises
                # PagePoolExhausted, if over-committed past live capacity)
                for i in active:
                    self._ensure_pages(i, self._sparse_tokens(
                        int(self.slot_pos[i])))
                # ship only a power-of-two bucket of logical pages: the
                # attention gather then materialises a view sized by LIVE
                # pages, not max_seq (transient memory follows tokens too);
                # one decode executable per bucket — O(log max_pages) total.
                # The upload itself is cached (dirty-flag) in _device_table.
                page_tab = self._device_table(self._page_bucket(active))
            else:
                page_tab = np.zeros((), np.int32)       # unused operand
            c0 = compile_events.total()
            t0 = time.perf_counter()
            logits, greedy, self.state = self._decode(
                self.params, self.next_tok, self.slot_pos, self.slot_k,
                page_tab, self.state)
            self._obs_dispatch("decode", time.perf_counter() - t0,
                               compiles=compile_events.total() - c0)
            self.dispatches["decode"] += 1
            self.metrics.counter("serve_dispatches_total",
                                 "jitted dispatches by kind",
                                 kind="decode").inc()
            if self.trace is not None:
                self.trace.emit("decode_dispatch", step=self.step_count,
                                lanes=len(active))
            picks = [(i, self.slots[i].req, len(self.slots[i].generated))
                     for i in active]
            if self.async_fetch:
                # start the device->host copy now, consume it at the top
                # of the NEXT step — the host does a full step of
                # scheduling work while the transfer is in flight
                self._pending = self._start_fetch(
                    logits, greedy, picks, self.step_count, lanes=active)
            else:
                t0 = time.perf_counter()
                toks = self._lane_tokens(logits, greedy, picks)
                self.metrics.histogram(
                    "serve_token_fetch_ms", DISPATCH_MS_BUCKETS,
                    "host block on the decode token transfer",
                    mode="sync").observe((time.perf_counter() - t0) * 1e3)
                self._apply_decode_tokens(active, toks)
        self.step_count += 1
        self._sample_gauges()
        if self._profiler is not None:
            self._profiler.step_end(self.step_count)
        return len(self.completions) - n_done0

    def profile_steps(self, n_steps: int, logdir: str) -> None:
        """Capture one ``jax.profiler`` trace spanning the next
        ``n_steps`` engine steps (admission, chunk dispatch and decode
        dispatch included) into ``logdir``."""
        self._profiler = StepProfiler(logdir, n_steps, trace=self.trace)

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_steps: Optional[int] = None) -> List[Completion]:
        """Submit ``requests`` and step until everything drains (or
        ``max_steps``).  Returns completions in finish order."""
        for r in requests or ():
            self.submit(r)
        n0 = len(self.completions)
        steps = 0
        while not self.done and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.completions[n0:]

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------

    def _n_attn(self) -> int:
        return sum(1 for i in range(self.cfg.n_layers)
                   if self.cfg.layer_kind(i) == "attn")

    def _cache_bytes(self) -> Dict[str, Any]:
        """Single source of truth for cache byte accounting.  Both
        :meth:`cache_report` and the per-step ``kv_cache_*`` /
        ``shard_kv_cache_*`` gauges read THIS, so the two surfaces can
        never drift apart (asserted in tests/test_obs_engine.py).

        Returns ``reserved_bytes`` / ``live_bytes`` totals plus a
        ``shards`` breakdown whose entries sum exactly to them
        (``shards`` is ``None`` for recurrent-state families, which have
        no row-granular layout to split); paged mode adds
        ``page_table_shipped_bytes``, the shipped table-prefix operand.
        """
        if self.api.init_paged_state is None:
            # recurrent-state families: analytic Eq. 1 bytes only
            b = serve_cache_report(self.cfg, self.swan, self.n_slots,
                                   self.max_seq)["bytes"]
            return {"reserved_bytes": b, "live_bytes": b, "shards": None}
        n_attn = self._n_attn()
        if not self.paged:
            # live = bytes resident in the state arrays; reserved = the
            # analytic worst-case layout.  The slab engine commits the
            # worst case at init, so the two must coincide — a real
            # invariant that catches layout/accounting drift.
            live = sum(x.nbytes for x in
                       jax.tree_util.tree_leaves(self.state))
            if self.swan is None:
                reserved = n_attn * hc.dense_cache_bytes(
                    self.cfg, self.n_slots, self.max_seq)
                shard_res = n_attn * hc.dense_cache_bytes(
                    self.cfg, self.n_local, self.max_seq)
            else:
                reserved = n_attn * (
                    hc.cache_bytes(self.cfg, self.swan, self.n_slots,
                                   self.max_seq)
                    + self.n_slots * self.swan.buffer * 4)      # buf_pos
                shard_res = n_attn * (
                    hc.cache_bytes(self.cfg, self.swan, self.n_local,
                                   self.max_seq)
                    + self.n_local * self.swan.buffer * 4)
            assert reserved == live, \
                f"slab reserved {reserved} != resident {live}"
            # the slab layout is linear in the batch axis, so each shard
            # carries exactly its slots' share
            return {"reserved_bytes": reserved, "live_bytes": reserved,
                    "shards": [{"reserved_bytes": shard_res,
                                "live_bytes": shard_res}
                               for _ in range(self.dp)]}
        page_b = pc.page_bytes(self.cfg, self.swan, self.pool.page_size)
        # device overhead counts the SHIPPED page-table prefix (the actual
        # per-step device operand), not the host-resident numpy table
        bucket = self._decode_bucket()
        overhead = (pc.ring_bytes(self.cfg, self.swan, self.n_slots)
                    + self.n_slots * bucket * 4)
        # per-shard: each shard owns its block of the pool, its slots'
        # rings, and its rows of the shipped table prefix (ring_bytes and
        # the table are linear in the batch axis, page blocks are equal by
        # construction — so the entries sum exactly to the totals)
        sh_over = (pc.ring_bytes(self.cfg, self.swan, self.n_local)
                   + self.n_local * bucket * 4)
        return {
            "reserved_bytes": self.pool.reserved_bytes(page_b) + overhead,
            "live_bytes": self.pool.live_bytes(page_b) + overhead,
            "page_table_shipped_bytes": self.n_slots * bucket * 4,
            "shards": [
                {"reserved_bytes":
                 self.pool.shard_reserved_bytes(s, page_b) + sh_over,
                 "live_bytes":
                 self.pool.shard_live_bytes(s, page_b) + sh_over,
                 "page_table_shipped_bytes": self.n_local * bucket * 4,
                 "live_pages": self.pool.shard_live_pages(s)}
                for s in range(self.dp)]}

    def _sample_gauges(self) -> None:
        """End-of-step gauge sampling (host-side).  Skipped entirely
        under the null registry — gauges are the only instrumentation
        with per-step cost, so ``metrics=False`` pays zero."""
        m = self.metrics
        if not m.enabled:
            return
        m.gauge("serve_engine_steps",
                "scheduler steps taken").set(self.step_count)
        m.gauge("serve_queue_depth",
                "arrived requests waiting for a slot").set(self.pending)
        m.gauge("serve_lanes_active",
                "slots holding a live request").set(self.n_active)
        acct = self._cache_bytes()
        m.gauge("kv_cache_reserved_bytes",
                "cache bytes physically allocated").set(
                    acct["reserved_bytes"])
        m.gauge("kv_cache_live_bytes",
                "cache bytes addressable by live tokens").set(
                    acct["live_bytes"])
        if "page_table_shipped_bytes" in acct:
            m.gauge("page_table_shipped_bytes",
                    "bytes of the shipped [n_slots, bucket] int32 "
                    "page-table prefix").set(
                        acct["page_table_shipped_bytes"])
        if self.paged:
            m.gauge("page_pool_live_pages",
                    "pages mapped to live sequences").set(
                        self.pool.live_pages)
            m.gauge("page_pool_free_pages",
                    "pages on the free lists").set(self.pool.free_pages)
        for sh in range(self.dp):
            lo = sh * self.n_local
            lanes = self.slots[lo:lo + self.n_local]
            m.gauge("shard_lanes_active",
                    "decoding lanes on this shard", shard=sh).set(
                        sum(1 for s in lanes
                            if s is not None and s.state == "decoding"))
            m.gauge("shard_lanes_prefilling",
                    "prefilling lanes on this shard", shard=sh).set(
                        sum(1 for s in lanes
                            if s is not None and s.state == "prefilling"))
            if acct["shards"] is not None:
                e = acct["shards"][sh]
                m.gauge("shard_kv_cache_reserved_bytes",
                        "cache bytes physically allocated on this shard",
                        shard=sh).set(e["reserved_bytes"])
                m.gauge("shard_kv_cache_live_bytes",
                        "live cache bytes on this shard", shard=sh).set(
                            e["live_bytes"])
            if self.paged:
                m.gauge("shard_page_pool_live_pages",
                        "live pages on this shard", shard=sh).set(
                            self.pool.shard_live_pages(sh))
                m.gauge("shard_page_pool_free_pages",
                        "free pages on this shard", shard=sh).set(
                            self.pool.shard_free_pages(sh))

    def cache_report(self) -> Dict[str, Any]:
        """Cache accounting across all slots, on ONE byte basis: the
        config's actual dtypes (the lockstep ``ServeSession`` keeps the
        paper's fp16 Eq. 1 view; the engine reports deployable bytes).

        Always reports BOTH ``reserved_bytes`` (physically allocated) and
        ``live_bytes`` (addressable by live tokens right now).  The slab
        engine commits the worst case up front, so the two coincide there
        (checked against the actually-resident state arrays); the paged
        engine is the one whose live bytes track generated tokens.

        ``shards`` breaks both down per mesh shard (one entry on a single
        device); the per-shard entries always sum exactly to the totals —
        asserted in tests/test_paged_engine.py.  All byte figures come
        from :meth:`_cache_bytes`, the same source the per-step
        ``kv_cache_*`` gauges sample.
        """
        rep = serve_cache_report(self.cfg, self.swan, self.n_slots,
                                 self.max_seq)
        if self.api.init_paged_state is None:
            # recurrent-state families: no row-granular layout to page or
            # audit — keep the analytic Eq. 1 report (no shard breakdown)
            rep["reserved_bytes"] = rep["live_bytes"] = rep["bytes"]
            return rep
        acct = self._cache_bytes()
        rep["reserved_bytes"] = acct["reserved_bytes"]
        rep["live_bytes"] = acct["live_bytes"]
        rep["shards"] = acct["shards"]
        dense_phys = self._n_attn() * hc.dense_cache_bytes(
            self.cfg, self.n_slots, self.max_seq)
        if not self.paged:
            rep["bytes"] = acct["reserved_bytes"]
            if self.swan is not None:
                rep["dense_bytes"] = dense_phys
                rep["saving"] = 1.0 - rep["bytes"] / dense_phys
            return rep
        rep["mode"] += "+paged"
        rep["slab_bytes"] = self._n_attn() * hc.cache_bytes(
            self.cfg, self.swan, self.n_slots, self.max_seq)
        rep["bytes"] = acct["live_bytes"]
        rep["dense_bytes"] = dense_phys
        rep["saving"] = 1.0 - acct["live_bytes"] / dense_phys
        rep.update(page_size=self.pool.page_size, n_pages=self.pool.n_pages,
                   live_pages=self.pool.live_pages)
        rep["page_table_shipped_bytes"] = acct["page_table_shipped_bytes"]
        return rep


def _dp_index(mesh, dpx):
    """This device's linear index along the mesh's data axes (inside
    shard_map)."""
    idx = jax.lax.axis_index(dpx[0])
    for a in dpx[1:]:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx
