"""Continuous-batching serve engine: request queue + slot scheduler over
per-sequence hybrid caches.

The lockstep ``ServeSession`` (one scalar ``pos`` for the whole batch)
wastes slots the moment sequences differ in length: everyone waits for the
longest prompt and the longest generation.  This engine admits and retires
sequences independently:

  * a FIFO request queue feeds ``n_slots`` cache slots;
  * each admission prefers the lowest free slot: the request's prompt is
    prefilled at batch=1 into a fresh single-slot state which is then
    written into the batched state (``dynamic_update_slice`` on axis 1 —
    every serve-state layout stacks layers in front of batch);
  * one jitted decode executable advances ALL active slots per engine step
    with per-sequence positions ``pos [B]`` (free slots idle at pos = -1;
    their lanes compute masked garbage that is never read);
  * finished sequences free their slot immediately — the next queued
    request backfills it on the same engine step.

Per-request SWAN ``k`` (the paper's runtime-tunable compression) rides
along as a traced ``[B]`` operand: a batch can mix compression levels and
the decode step still compiles exactly once (see
``decode_cache_size`` — asserted by tests/test_serve_engine.py).

Prefill compiles once per distinct prompt length (XLA static shapes).
Production would bucket prompt lengths; left open in ROADMAP.md.
"""
from __future__ import annotations

import inspect
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model, swan_applicable
from repro.runtime.serve_loop import serve_cache_report

Params = Dict[str, Any]


@dataclass
class Request:
    """One generation request.

    ``k``: optional per-request SWAN retention override (<= swan.k_max) —
    the runtime compression knob, tunable per request without recompiling.
    ``arrival_step``: engine step at which the request becomes visible
    (deterministic trace replay; 0 = already waiting).
    """
    uid: Any
    tokens: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos: Optional[int] = None
    k: Optional[int] = None
    arrival_step: int = 0


@dataclass
class Completion:
    uid: Any
    tokens: List[int]
    prompt_len: int
    k: Optional[int]
    admitted_step: int
    finished_step: int


@dataclass
class _Slot:
    req: Request
    generated: List[int] = field(default_factory=list)
    admitted_step: int = 0


class ServeEngine:
    """Continuous-batching generation over a slot-based batched cache."""

    def __init__(self, cfg, params, swan=None, projections=None,
                 max_seq: int = 4096, n_slots: int = 4, jit: bool = True):
        self.cfg = cfg
        if cfg.is_encoder_decoder:
            raise NotImplementedError(
                "encoder-decoder serving needs per-request encoder frames; "
                "use the lockstep ServeSession for whisper-style models")
        self.api = get_model(cfg)
        self.swan = swan if (swan and swan.enabled and swan_applicable(cfg)) else None
        self.projections = projections
        self.max_seq = max_seq
        self.n_slots = n_slots
        if self.swan is not None:
            self.swan.validate(cfg.d_head)
            if projections is None:
                raise ValueError("SWAN enabled but no projections given — "
                                 "run calibrate_swan first")
        self.params = params
        self.state = self.api.init_serve_state(cfg, self.swan, n_slots, max_seq)
        sw, pj = self.swan, self.projections
        # per-request k needs the family to thread k_active through
        # prefill/decode (transformer families: dense/moe/vlm; jamba/ssm
        # serve with their fixed config-level k)
        self._k_threading = (
            self.swan is not None
            and "k_active" in inspect.signature(self.api.prefill).parameters
            and "k_active" in inspect.signature(self.api.decode_step).parameters)
        k_fill = 0 if self.swan is None else self.swan.k_max

        if self._k_threading:
            def prefill_fn(p, batch_in, state, k_act):
                return self.api.prefill(p, cfg, batch_in, state, sw, pj,
                                        k_active=k_act)

            def decode_fn(p, token, pos, k_act, state):
                return self.api.decode_step(p, cfg, token, pos, state, sw, pj,
                                            k_active=k_act)
        else:
            def prefill_fn(p, batch_in, state, k_act):
                return self.api.prefill(p, cfg, batch_in, state, sw, pj)

            def decode_fn(p, token, pos, k_act, state):
                return self.api.decode_step(p, cfg, token, pos, state, sw, pj)

        def insert_fn(big, one, slot):
            return jax.tree_util.tree_map(
                lambda b, o: jax.lax.dynamic_update_slice_in_dim(
                    b, o.astype(b.dtype), slot, axis=1), big, one)

        if jit:
            self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
            self._decode = jax.jit(decode_fn, donate_argnums=(4,))
            self._insert = jax.jit(insert_fn, donate_argnums=(0,))
        else:
            self._prefill, self._decode, self._insert = \
                prefill_fn, decode_fn, insert_fn

        self.queue: deque[Request] = deque()
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.slot_pos = np.full((n_slots,), -1, np.int32)   # next decode position
        self.slot_k = np.full((n_slots,), k_fill, np.int32)
        self.next_tok = np.zeros((n_slots,), np.int32)
        self.step_count = 0
        self.completions: List[Completion] = []

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.tokens) + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.uid}: {len(req.tokens)}+{req.max_new_tokens} "
                f"tokens exceed max_seq={self.max_seq}")
        if req.k is not None:
            if self.swan is None:
                raise ValueError(f"request {req.uid}: per-request k needs SWAN")
            if not self._k_threading:
                raise ValueError(f"{self.cfg.family!r} family does not "
                                 "support per-request k overrides")
            if req.k > self.swan.k_max:
                raise ValueError(f"request {req.uid}: k={req.k} > allocated "
                                 f"k_max={self.swan.k_max}")
        self.queue.append(req)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def pending(self) -> int:
        return sum(r.arrival_step <= self.step_count for r in self.queue)

    @property
    def done(self) -> bool:
        return not self.queue and self.n_active == 0

    @property
    def decode_cache_size(self) -> int:
        """Compiled decode executables (1 == mixed-k batches share one)."""
        size = getattr(self._decode, "_cache_size", None)
        return size() if callable(size) else -1

    def _sample(self, logits, req: Request, n_prev: int) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(np.asarray(logits)))
        key = jax.random.fold_in(jax.random.PRNGKey(req.seed), n_prev)
        return int(jax.random.categorical(
            key, jnp.asarray(logits) / req.temperature))

    def _admit(self, req: Request, slot: int) -> None:
        state1 = self.api.init_serve_state(self.cfg, self.swan, 1, self.max_seq)
        tokens = jnp.asarray(np.asarray(req.tokens, np.int32))[None]
        k_req = self.swan.k_max if (self.swan and req.k is None) else (req.k or 0)
        logits, state1 = self._prefill(self.params, {"tokens": tokens}, state1,
                                       jnp.asarray(k_req, jnp.int32))
        self.state = self._insert(self.state, state1,
                                  jnp.asarray(slot, jnp.int32))
        s = _Slot(req=req, admitted_step=self.step_count)
        first = self._sample(logits[0, -1], req, 0)
        s.generated.append(first)
        self.slots[slot] = s
        self.slot_pos[slot] = len(req.tokens)
        self.slot_k[slot] = k_req
        self.next_tok[slot] = first
        self._maybe_retire(slot)

    def _maybe_retire(self, slot: int) -> None:
        s = self.slots[slot]
        done = (len(s.generated) >= s.req.max_new_tokens
                or (s.req.eos is not None and s.generated[-1] == s.req.eos)
                or self.slot_pos[slot] >= self.max_seq)
        if not done:
            return
        self.completions.append(Completion(
            uid=s.req.uid, tokens=list(s.generated),
            prompt_len=len(s.req.tokens), k=s.req.k,
            admitted_step=s.admitted_step, finished_step=self.step_count))
        self.slots[slot] = None
        self.slot_pos[slot] = -1
        self.slot_k[slot] = self.swan.k_max if self.swan else 0
        self.next_tok[slot] = 0

    def _admit_pending(self) -> None:
        while self.n_active < self.n_slots:
            nxt = next((r for r in self.queue
                        if r.arrival_step <= self.step_count), None)
            if nxt is None:
                return
            self.queue.remove(nxt)
            slot = self.slots.index(None)
            self._admit(nxt, slot)

    # ------------------------------------------------------------------
    # Engine step
    # ------------------------------------------------------------------

    def step(self) -> int:
        """One scheduler iteration: admit → batched decode → retire.
        Returns the number of sequences that finished this step."""
        n_done0 = len(self.completions)
        self._admit_pending()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if active:
            logits, self.state = self._decode(
                self.params, jnp.asarray(self.next_tok),
                jnp.asarray(self.slot_pos), jnp.asarray(self.slot_k),
                self.state)
            logits = np.asarray(logits)      # one host transfer per step
            for i in active:
                self.slot_pos[i] += 1
                s = self.slots[i]
                tok = self._sample(logits[i], s.req, len(s.generated))
                s.generated.append(tok)
                self.next_tok[i] = tok
                self._maybe_retire(i)
        self.step_count += 1
        return len(self.completions) - n_done0

    def run(self, requests: Optional[Sequence[Request]] = None,
            max_steps: Optional[int] = None) -> List[Completion]:
        """Submit ``requests`` and step until everything drains (or
        ``max_steps``).  Returns completions in finish order."""
        for r in requests or ():
            self.submit(r)
        n0 = len(self.completions)
        steps = 0
        while not self.done and (max_steps is None or steps < max_steps):
            self.step()
            steps += 1
        return self.completions[n0:]

    # ------------------------------------------------------------------
    # Accounting
    # ------------------------------------------------------------------

    def cache_report(self) -> Dict[str, Any]:
        """Physical cache accounting (paper Eq. 1 across all slots)."""
        return serve_cache_report(self.cfg, self.swan, self.n_slots,
                                  self.max_seq)
