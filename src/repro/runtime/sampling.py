"""Token sampling shared by ``ServeSession`` and ``ServeEngine``.

One helper, one numerical contract: logits are cast to float32 BEFORE the
temperature divide.  Dividing raw bf16 logits first re-rounds the whole
distribution to ~8 significand bits and can flip near-tie samples between
otherwise-identical runs — the two previous per-class copies of this code
both had that bug.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_token(logits: jnp.ndarray, temperature: float,
                 key) -> jnp.ndarray:
    """Greedy (``temperature <= 0``) or temperature sampling over
    ``logits [..., V]``.  Returns int32 token ids with the batch shape of
    ``logits``; the PRNG ``key`` is only consumed on the temperature path.
    """
    logits = jnp.asarray(logits, jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)
