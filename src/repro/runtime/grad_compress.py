"""Gradient compression (distributed-optimization trick, DESIGN.md §4).

``compress_gradients`` applies a quantize/dequantize (int8, per-tensor-chunk
scale) round to the gradients *before* the optimizer.  Under SPMD the
gradient all-reduce happens where XLA placed it; expressing the compression
as quant→dequant around the reduction point lets the compiler carry the
int8 representation across the collective when profitable, and in the
shard_map DP path (``dp_int8_allreduce``) the wire format is explicitly
int8: 4× less cross-pod gradient traffic.

Error feedback (§ Karimireddy et al.): the quantization residual is returned
so callers can fold it into the next step (kept optional; the plain path is
stateless).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any
CHUNK = 4096


def _quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-CHUNK symmetric int8.  Returns (q int8 [n_chunks, CHUNK], scale)."""
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % CHUNK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(flat), axis=1, keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_tree(grads: Tree) -> Tree:
    return jax.tree_util.tree_map(_quantize_leaf, grads)


def compress_gradients(grads: Tree, error_feedback: Tree = None) -> Tree:
    """Quant→dequant round (lossy).  With ``error_feedback``, residuals are
    added before quantization and the new residuals replace the tree in
    place (caller keeps it)."""
    def one(g, e=None):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, s = _quantize_leaf(g32)
        deq = _dequantize_leaf(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype)

    if error_feedback is None:
        return jax.tree_util.tree_map(one, grads)
    return jax.tree_util.tree_map(one, grads, error_feedback)


def residuals(grads: Tree) -> Tree:
    """Quantization residual per leaf (for error-feedback accumulation)."""
    def one(g):
        g32 = g.astype(jnp.float32)
        q, s = _quantize_leaf(g32)
        deq = _dequantize_leaf(q, s, g.shape, jnp.float32)
        return g32 - deq
    return jax.tree_util.tree_map(one, grads)


# ---------------------------------------------------------------------------
# Explicit int8-on-the-wire DP all-reduce (shard_map path)
# ---------------------------------------------------------------------------

def dp_int8_allreduce(grads: Tree, axis_name: str) -> Tree:
    """Mean-reduce gradients across a data-parallel axis with int8 wire
    format: quantize locally, all_gather int8 (+f32 scales), dequantize and
    average locally.  4x less gradient traffic than f32 psum at the cost of
    one quantization round per step.  Use inside shard_map."""
    def one(g):
        q, s = _quantize_leaf(g)
        qg = jax.lax.all_gather(q, axis_name)        # [P, n_chunks, CHUNK] int8
        sg = jax.lax.all_gather(s, axis_name)
        deq = qg.astype(jnp.float32) * sg            # [P, n_chunks, CHUNK]
        mean = deq.mean(axis=0)
        n = 1
        for d in g.shape:
            n *= d
        return mean.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)
    return jax.tree_util.tree_map(one, grads)
