"""GPipe-style pipeline parallelism via shard_map + ppermute.

The layer stack is split into P contiguous stages along a 'pipe' mesh axis;
microbatches stream through with the classic (M + P - 1)-tick schedule.
Forward is written with lax.scan over ticks + lax.ppermute stage shifts;
the 1F1B-ish backward emerges from jax autodiff (ppermute transposes to the
reverse shift), so ``jax.grad`` of a pipelined loss just works.

This is an optional beyond-paper extension (DESIGN.md §4): the default
dry-run meshes use DP×TP(+pod); PP composes by adding a 'pipe' axis.

Constraints: homogeneous stacked layers [L, ...] with L % P == 0; global
batch % n_micro == 0; the residual-stream shape is constant across layers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Params = Dict[str, Any]


def split_stages(stacked_params: Params, n_stages: int) -> Params:
    """[L, ...] leaves -> [P, L/P, ...] (stage-major) for sharding on axis 0."""
    def resh(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])
    return jax.tree_util.tree_map(resh, stacked_params)


def pipeline_apply(layer_fn: Callable, stage_params: Params, x: jnp.ndarray,
                   n_micro: int, mesh: Mesh, axis: str = "pipe") -> jnp.ndarray:
    """Run x [B, ...] through the pipelined layer stack.

    ``layer_fn(layer_params, x_micro) -> x_micro`` applies ONE layer;
    ``stage_params`` leaves are [P, L/P, ...] (see split_stages).
    Returns the full-batch output, replicated over the pipe axis.
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro
    micro = x.reshape(n_micro, mb, *x.shape[1:])
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def stage_apply(params_local, xm):
        def body(x, lp):
            return layer_fn(lp, x), None
        out, _ = jax.lax.scan(body, xm, params_local)
        return out

    def local_fn(params_stage, micro_in):
        # params_stage: [1, L/P, ...] (this device's stage), micro_in: [M, mb, ...]
        params_local = jax.tree_util.tree_map(lambda t: t[0], params_stage)
        stage = jax.lax.axis_index(axis)
        n_ticks = n_micro + n_stages - 1
        zero = jnp.zeros_like(micro_in[0])
        outputs0 = jnp.zeros_like(micro_in)

        def tick(carry, t):
            recv, outputs = carry
            mi = jnp.clip(t, 0, n_micro - 1)
            inject = jnp.where(stage == 0,
                               jnp.asarray(True), jnp.asarray(False))
            inp = jnp.where(inject, micro_in[mi], recv)
            out = stage_apply(params_local, inp)
            # collect finished microbatch at the last stage
            oi = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = (stage == n_stages - 1) & (t >= n_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, jnp.where(take, out, outputs[oi]), oi, axis=0)
            recv_next = (jax.lax.ppermute(out, axis, fwd_perm)
                         if n_stages > 1 else out)
            return (recv_next, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (zero, outputs0),
                                       jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via psum
        outputs = jnp.where(stage == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis)

    from repro.sharding.api import shard_map_compat

    in_specs = (P(axis), P())       # stage params sharded; input replicated
    out = shard_map_compat(local_fn, mesh, in_specs,
                           P())(stage_params, micro)
    return out.reshape(B, *x.shape[1:])
