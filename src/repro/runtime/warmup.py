"""AOT executable-family warmup for :class:`repro.runtime.serve_engine.
ServeEngine`.

Under real traffic the first request to hit each (prompt-chunk x lane x
slab-read-prefix / page-table-prefix) shape bucket eats a multi-second XLA
compile in the middle of serving.  Every bucket is enumerable from the
engine's STATIC config, so this module enumerates the complete family
(:func:`executable_family`) and pre-compiles it at startup
(:func:`warmup_engine`) — after which a randomized mixed workload triggers
ZERO new compiles (machine-checked by the swanlint Layer-2 audit and
``benchmarks/bench_warmup.py`` via ``repro.obs.compile_events``).

Why dummy dispatches instead of ``jit(...).lower(...).compile()``: an AOT
``lower().compile()`` produces a compiled artifact but does NOT populate
the jit callable's dispatch cache — the first real call would re-trace and
re-compile anyway (verified empirically: ``_cache_size()`` stays put after
``lower().compile()`` and bumps on a real call).  So warmup drives the
SAME jitted callables ``step()`` dispatches through, with dead-lane no-op
operands the engine's own scheduling contract already guarantees are
side-effect-free:

* decode with every lane at ``pos = -1`` — the dead-lane rule from chunked
  prefill (ring untouched, sparse/dense writes dropped or sent to the
  shard's trash page);
* chunk with every lane's slot parked at the out-of-range local index
  ``n_local`` — exactly how ``_advance_prefills`` pads unused lanes;
* monolithic-admission prefill into a fresh batch=1 transient, then the
  insert parked at global slot ``n_slots`` (scatter ``mode="drop"`` /
  trash-page rows).

State leaves are donated into those dispatches, so the engine's ``state``
is re-bound to each call's output — contents are bit-identical (warmed ==
unwarmed engines are token-identical, gated in tests/test_warmup.py).

The family also includes the EAGER executables on the serve path, which
the per-dispatch jit census cannot see but the zero-compile gate does: the
power-of-two-bucketed temperature-row gather + async host copies
(``_start_fetch``), the admission logits-row slice, and the
``sample_token``/PRNG ops behind ``_sample``.

Growth executables (``pool_grow``) are the one family NOT warmed here:
their shape depends on the runtime growth sequence, growth is a rare
control-plane event, and ``_grow_pool`` re-warms the whole family anyway
(the pool leaf changes shape, staleing every state-carrying executable).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from types import SimpleNamespace
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import paged_cache as pc
from repro.obs import compile_events

_COMPILE_HELP = "XLA backend compiles by phase (warmup vs mid-serve)"


@dataclass(frozen=True)
class WarmupItem:
    """One warm dispatch: a (kind, shape-bucket) the scheduler can legally
    request.  ``detail`` is the human-readable bucket key that lands in
    the warmup report and the bench rows."""
    kind: str                           # decode|chunk|prefill|fetch|sample
    page_bucket: Optional[int] = None   # shipped table width (paged)
    n_lanes: int = 0                    # chunk lane width (dp * Pl)
    chunk: int = 0                      # chunk token width C
    prefix: Optional[int] = None        # slab read-prefix bucket
    pad_len: int = 0                    # monolithic prompt bucket
    width: int = 0                      # fetch: temp-row gather width
    src: str = ""                       # fetch: "decode" or "chunk"

    @property
    def detail(self) -> str:
        if self.kind == "decode":
            return (f"page_bucket={self.page_bucket}"
                    if self.page_bucket is not None else "slab")
        if self.kind == "chunk":
            tail = (f"page_bucket={self.page_bucket}"
                    if self.page_bucket is not None
                    else f"prefix={self.prefix}")
            return f"lanes={self.n_lanes} C={self.chunk} {tail}"
        if self.kind == "prefill":
            return f"pad={self.pad_len}"
        if self.kind == "fetch":
            return f"{self.src} lanes={self.n_lanes} rows={self.width}"
        return self.kind


def _pow2_buckets(cap: int) -> List[int]:
    """Every power-of-two value ``min(_pow2(x), cap)`` can take for
    ``x in 1..cap`` — the engine's universal bucket rule: powers of two up
    to ``cap``, plus ``cap`` itself when it is not one (the clamp)."""
    out: List[int] = []
    b = 1
    while b <= cap:
        out.append(b)
        b <<= 1
    if not out or out[-1] != cap:
        out.append(cap)
    return out


def executable_family(eng, max_prompt_len: Optional[int] = None
                      ) -> Dict[str, Any]:
    """Enumerate every executable bucket the scheduler can legally request
    from ``eng``'s static config.

    Returns ``{"items": [WarmupItem...], "expected": {...}, "skipped":
    [...]}`` where ``expected`` mirrors :meth:`ServeEngine.
    executable_census`'s keys — per-family compiled-executable counts a
    fully-warmed engine must meet (the Layer-2 audit asserts
    ``census >= expected`` bucket by bucket).  ``items`` are ordered so a
    fetch item always follows the dispatch item that produces its source
    logits.  ``max_prompt_len`` (admission-side bound on prompt tokens)
    trims the slab read-prefix and monolithic pad families."""
    items: List[WarmupItem] = []
    skipped: List[str] = []
    prompt_cap = min(max_prompt_len or eng.max_seq, eng.max_seq)
    prompt_pow2 = eng._pow2(prompt_cap)

    # --- decode family -------------------------------------------------
    if eng.paged:
        widths = _pow2_buckets(eng.pool.pages_per_seq)
        items += [WarmupItem("decode", page_bucket=w) for w in widths]
        n_decode = len(widths)
    else:
        items.append(WarmupItem("decode"))
        n_decode = 1
    # temperature-row gather over decode logits [n_slots, V]
    items += [WarmupItem("fetch", src="decode", n_lanes=eng.n_slots,
                         width=w) for w in _pow2_buckets(eng.n_slots)]

    # --- chunk family (chunked prefill) --------------------------------
    exp_chunk: Dict[str, int] = {}
    n_prefill = n_insert = n_insert_paged = 0
    if eng.prefill_chunk is not None:
        pl_buckets = _pow2_buckets(eng._pow2(eng.prefill_slots))
        c_buckets = _pow2_buckets(eng.prefill_chunk)
        for pl in pl_buckets:
            lanes = eng.dp * pl
            for c in c_buckets:
                if eng.paged:
                    for w in _pow2_buckets(eng.pool.pages_per_seq):
                        items.append(WarmupItem(
                            "chunk", n_lanes=lanes, chunk=c, page_bucket=w))
                        exp_chunk["paged"] = exp_chunk.get("paged", 0) + 1
                else:
                    # prefix = min(pow2(start_max + C), max_seq) with
                    # start >= 0 => every pow2 bucket in [C, prompt bound]
                    for p in _pow2_buckets(eng.max_seq):
                        if p < c or p > max(prompt_pow2, c):
                            continue
                        items.append(WarmupItem(
                            "chunk", n_lanes=lanes, chunk=c, prefix=p))
                        exp_chunk[str(p)] = exp_chunk.get(str(p), 0) + 1
            items += [WarmupItem("fetch", src="chunk", n_lanes=lanes,
                                 width=w) for w in _pow2_buckets(lanes)]
    elif eng._bucketing:
        # monolithic admission: one (prefill, insert) pair per prompt
        # pad bucket
        pads = [b for b in _pow2_buckets(eng.max_seq) if b <= prompt_pow2]
        items += [WarmupItem("prefill", pad_len=b) for b in pads]
        n_prefill = len(pads)
        if eng.paged:
            n_insert_paged = len(pads)
        else:
            n_insert = len(pads)
    else:
        skipped.append(
            "monolithic prefill with bucket_prompts=False compiles once "
            "per distinct prompt length — an unbounded family warmup "
            "cannot enumerate")
    items.append(WarmupItem("sample"))

    return {
        "items": items,
        "expected": {"decode": n_decode, "prefill": n_prefill,
                     "chunk": exp_chunk, "insert": n_insert,
                     "insert_paged": n_insert_paged},
        "skipped": skipped,
    }


def _warm_fetch(eng, logits, greedy, width: int) -> None:
    """Compile the async token-fetch path for one temperature-lane bucket
    against REAL dispatch outputs (right shape, dtype and sharding): the
    padded row gather, both ``copy_to_host_async`` transfers, and the
    host conversions."""
    idx = np.zeros((width,), np.int32)
    rows = logits[jnp.asarray(idx)]
    rows.copy_to_host_async()
    greedy.copy_to_host_async()
    np.asarray(rows)
    np.asarray(greedy)


def warmup_engine(eng, max_prompt_len: Optional[int] = None
                  ) -> Dict[str, Any]:
    """Pre-compile ``eng``'s whole executable family (see module
    docstring).  Returns the warmup report::

        {"warmup_ms": ..., "compiles": ..., "items": [{kind, detail,
         compiles, ms}...], "by_kind": {kind: {"items", "compiles"}},
         "expected": <family expectation>, "census": <executable_census>,
         "skipped": [...]}

    and records ``serve_warmup_ms`` / ``serve_compile_total{phase=
    "warmup"}`` in the engine's metrics registry plus ``warmup`` trace
    events.  Safe to call mid-serve (pool growth does): the dummy
    operands are dead-lane no-ops, so live sequences are untouched."""
    if not eng._jit:
        raise RuntimeError("warmup requires jit=True — a no-jit engine "
                           "has no executables to pre-compile")
    fam = executable_family(eng, max_prompt_len=max_prompt_len)
    t_start = time.perf_counter()
    c_start = compile_events.total()
    if eng.trace is not None:
        eng.trace.emit("warmup_start", step=eng.step_count,
                       n_items=len(fam["items"]))

    rows: List[Dict[str, Any]] = []

    def timed(item: WarmupItem, fn) -> Any:
        c0 = compile_events.total()
        t0 = time.perf_counter()
        out = fn()
        dc = compile_events.total() - c0
        rows.append({"kind": item.kind, "detail": item.detail,
                     "compiles": dc,
                     "ms": (time.perf_counter() - t0) * 1e3})
        if dc:
            eng.metrics.counter("serve_compile_total", _COMPILE_HELP,
                                phase="warmup", kind=item.kind).inc(dc)
        return out

    # dead-lane decode operands: pos = -1 everywhere, exactly the state a
    # fresh engine decodes with while every slot is still prefilling
    dead_tok = np.zeros((eng.n_slots,), np.int32)
    dead_pos = np.full((eng.n_slots,), -1, np.int32)
    dead_k = np.full((eng.n_slots,), eng._k_fill, np.int32)
    # last dispatch outputs per fetch source, keyed by (src, n_lanes)
    last: Dict[Any, Any] = {}

    for item in fam["items"]:
        if item.kind == "decode":
            tab = (eng._device_table(item.page_bucket)
                   if item.page_bucket is not None
                   else np.zeros((), np.int32))

            def run_decode(tab=tab):
                logits, greedy, state = eng._decode(
                    eng.params, dead_tok, dead_pos, dead_k, tab, eng.state)
                eng.state = state
                return logits, greedy
            last[("decode", eng.n_slots)] = timed(item, run_decode)

        elif item.kind == "chunk":
            lanes = item.n_lanes
            toks = np.zeros((lanes, item.chunk), np.int32)
            slot_v = np.full((lanes,), eng.n_local, np.int32)  # parked OOB
            start_v = np.zeros((lanes,), np.int32)
            tlen_v = np.ones((lanes,), np.int32)
            k_v = np.full((lanes,), eng._k_fill, np.int32)
            tab = (eng._device_table(item.page_bucket)
                   if item.page_bucket is not None
                   else np.zeros((), np.int32))

            def run_chunk(toks=toks, slot_v=slot_v, start_v=start_v,
                          tlen_v=tlen_v, k_v=k_v, tab=tab,
                          prefix=item.prefix):
                logits, greedy, state = eng._chunk_call(
                    eng.params, toks, eng.state, slot_v, start_v, k_v,
                    tlen_v, tab, prefix=prefix)
                eng.state = state
                return logits, greedy
            last[("chunk", lanes)] = timed(item, run_chunk)

        elif item.kind == "prefill":
            pad = item.pad_len
            if eng.paged:
                ps = eng.pool.page_size
                s1 = -(-pad // ps) * ps
            else:
                s1 = eng.max_seq

            def run_prefill(pad=pad, s1=s1):
                state1 = eng.api.init_serve_state(eng.cfg, eng.swan, 1, s1)
                toks = np.zeros((pad,), np.int32)
                logits, state1 = eng._prefill(
                    eng.params, {"tokens": toks[None]}, state1,
                    np.int32(eng._k_fill), np.int32(1))
                np.asarray(logits[0, -1])     # admission-row slice + copy
                if eng.paged:
                    trash = np.full((s1 // eng.pool.page_size,),
                                    pc.TRASH_PAGE, np.int32)
                    # parked at slot n_slots: ring writes drop, pool
                    # writes land on the trash page
                    eng.state = eng._insert_paged(
                        eng.state, state1, np.int32(eng.n_slots), trash)
                else:
                    eng.state = eng._insert(eng.state, state1,
                                            np.int32(eng.n_slots))
            timed(item, run_prefill)

        elif item.kind == "fetch":
            src = last.get((item.src, item.n_lanes))
            if src is None:
                continue
            timed(item, lambda src=src, w=item.width:
                  _warm_fetch(eng, src[0], src[1], w))

        elif item.kind == "sample":
            dec = last[("decode", eng.n_slots)]
            row = np.asarray(dec[0])[0]       # real dtype/width [V] row

            def run_sample(row=row):
                eng._sample(row, SimpleNamespace(temperature=1.0, seed=0),
                            0)
            timed(item, run_sample)

    warmup_ms = (time.perf_counter() - t_start) * 1e3
    compiles = compile_events.total() - c_start
    by_kind: Dict[str, Dict[str, int]] = {}
    for r in rows:
        agg = by_kind.setdefault(r["kind"], {"items": 0, "compiles": 0})
        agg["items"] += 1
        agg["compiles"] += r["compiles"]
    report = {"warmup_ms": warmup_ms, "compiles": compiles, "items": rows,
              "by_kind": by_kind, "expected": fam["expected"],
              "census": eng.executable_census(),
              "skipped": fam["skipped"]}
    eng.metrics.gauge("serve_warmup_ms",
                      "wall time of the last executable-family warmup"
                      ).set(warmup_ms)
    if eng.trace is not None:
        eng.trace.emit("warmup_done", step=eng.step_count,
                       n_items=len(rows), compiles=compiles,
                       warmup_ms=warmup_ms)
    return report


def enable_compilation_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path`` so engine
    restarts reload compiled executables from disk instead of recompiling
    the family (`--compilation-cache-dir` on ``launch/serve.py``).  The
    threshold knobs are best-effort (older releases lack them): serve
    executables are small and the whole point is caching everything."""
    import jax
    jax.config.update("jax_compilation_cache_dir", str(path))
    for opt, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                     ("jax_persistent_cache_min_entry_size_bytes", -1)):
        try:
            jax.config.update(opt, val)
        except Exception:        # knob absent on this release — fine
            pass
