"""Host-side page allocator for the paged sparse KV cache.

The device keeps one shared pool of fixed-size pages per layer (see
``repro.core.paged_cache``); this module owns the *mapping*: which physical
page backs which (slot, logical-page) pair.  All allocator state is plain
numpy on the host — the scheduler already runs there, and the page table is
shipped to the device as a tiny ``[n_slots, pages_per_seq]`` int32 operand
each step.

Sharding (``n_shards > 1``): under the mesh-sharded serve engine the device
pool's page axis is partitioned over the mesh's ``data`` axis, exactly like
the slab batch axis.  The allocator mirrors that: physical pages are split
into ``n_shards`` equal blocks, slot ``s`` belongs to shard
``s // slots_per_shard``, and a slot only ever maps pages from its own
shard's free list.  Table entries store SHARD-LOCAL physical indices (what
the device sees inside its ``shard_map`` block), and every shard has its
OWN local trash page 0 — a redirected garbage write therefore never
crosses shards.  ``n_shards=1`` is exactly the old single-device pool.

Invariants (enforced, and property-tested in tests/test_page_pool.py):

  * local physical page 0 of every shard is a TRASH page: it is never
    allocated, and every unmapped page-table entry points at it.  Clamped
    garbage writes (the hybrid cache's pos < buffer eviction trick) and
    gathers of not-yet-live logical pages all land there, where validity
    masks hide them;
  * a non-trash physical page is owned by at most one slot at a time — two
    live sequences can never alias storage (and slots on different shards
    can never even address each other's pages);
  * ``free_slot`` returns pages to its shard's free list immediately, so a
    request backfilled into the slot on the same engine step reuses them;
  * exhaustion raises ``PagePoolExhausted`` (a clean, catchable error)
    without corrupting allocator state;
  * reservations (``reserve``): a chunked prefill maps its pages one chunk
    at a time, so admission places a HOLD for the prompt's whole winnow
    need — the slot's own allocations consume the hold first, and no other
    slot may dip into held stock.  This closes the check-without-reserve
    race where a decoding slot's growth (or a same-step second admission)
    starves an already-admitted in-flight prefill;
  * ``grow`` extends every shard's block by the same page count (the device
    pool's page axis must stay evenly partitioned): existing local indices
    — and therefore the whole page table — stay valid, and the new pages
    join the BACK of each free list so warm just-freed pages are still
    handed out first.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.core.paged_cache import TRASH_PAGE  # single source of truth
from repro.obs.metrics import NULL_REGISTRY, MetricsRegistry
from repro.obs.trace import EventTrace


class PagePoolExhausted(RuntimeError):
    """No free physical pages left — the pool is over-committed."""


class PagePool:
    """Free-list allocator over ``n_pages`` physical pages in ``n_shards``
    equal shard blocks.

    ``table[slot, j]`` is the SHARD-LOCAL physical page backing logical
    page ``j`` of ``slot`` (0 = unmapped / that shard's trash page).
    Logical pages are mapped densely from 0 upward — the hybrid cache
    writes winnowed tokens in position order, so a sequence's mapping only
    ever grows at the end (until the slot is freed wholesale on
    retirement).
    """

    def __init__(self, n_pages: int, pages_per_seq: int, n_slots: int,
                 page_size: int, n_shards: int = 1):
        if n_shards < 1:
            raise ValueError(f"n_shards={n_shards} must be >= 1")
        if n_pages % n_shards:
            raise ValueError(f"n_pages={n_pages} not divisible by "
                             f"n_shards={n_shards}")
        if n_slots % n_shards:
            raise ValueError(f"n_slots={n_slots} not divisible by "
                             f"n_shards={n_shards}")
        if n_pages // n_shards < 2:
            raise ValueError("need >= 2 pages per shard (local page 0 is "
                             "reserved as trash)")
        self.n_pages = n_pages
        self.pages_per_seq = pages_per_seq
        self.n_slots = n_slots
        self.page_size = page_size
        self.n_shards = n_shards
        self.pages_per_shard = n_pages // n_shards
        self.slots_per_shard = n_slots // n_shards
        # LIFO free lists (one per shard, local indices): a just-retired
        # sequence's pages are the next ones handed out (warm reuse)
        self._free: List[List[int]] = [
            list(range(self.pages_per_shard - 1, 0, -1))
            for _ in range(n_shards)]
        self.table = np.full((n_slots, pages_per_seq), TRASH_PAGE, np.int32)
        self.n_mapped = np.zeros((n_slots,), np.int64)
        # owner[shard, local_page] = slot (-1 = free/trash)
        self._owner = np.full((n_shards, self.pages_per_shard), -1, np.int64)
        self._held = np.zeros((n_slots,), np.int64)       # outstanding holds
        # dirty counter: bumped on every ``table`` mutation so the engine
        # can cache device uploads of table prefixes and re-ship only when
        # the mapping actually changed (most decode steps map nothing)
        self.version = 0
        # observability sink (bind_obs): page map/free/exhaustion events
        # and counters are emitted host-side, never from jitted code
        self._metrics: MetricsRegistry = NULL_REGISTRY
        self._trace: Optional[EventTrace] = None
        self._step: Callable[[], int] = lambda: 0

    def bind_obs(self, metrics: Optional[MetricsRegistry] = None,
                 trace: Optional[EventTrace] = None,
                 step_fn: Optional[Callable[[], int]] = None) -> None:
        """Attach an observability sink: ``metrics`` receives
        ``page_pool_*`` counters, ``trace`` receives ``page_map`` /
        ``page_free`` / ``pool_exhausted`` events stamped with the engine
        step from ``step_fn``.  Purely additive — allocator behaviour is
        identical bound or unbound."""
        self._metrics = metrics if metrics is not None else NULL_REGISTRY
        self._trace = trace
        if step_fn is not None:
            self._step = step_fn

    def _exhausted(self, msg: str, slot: int) -> PagePoolExhausted:
        self._metrics.counter(
            "page_pool_exhausted_total",
            "allocation attempts that found no eligible free page").inc()
        if self._trace is not None:
            self._trace.emit("pool_exhausted", step=self._step(), slot=slot,
                             shard=self.shard_of(slot), detail=msg)
        return PagePoolExhausted(msg)

    def shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    # ------------------------------------------------------------------
    # Allocation
    # ------------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Logical pages needed to hold ``n_tokens`` sparse tokens."""
        return -(-max(int(n_tokens), 0) // self.page_size)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s mapping to cover ``n_tokens`` sparse tokens."""
        need = self.pages_for(n_tokens)
        if need > self.pages_per_seq:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens need {need} pages "
                f"> pages_per_seq={self.pages_per_seq}")
        while self.n_mapped[slot] < need:
            self._alloc_one(slot)

    def reserve(self, slot: int, n_pages: int) -> None:
        """Place a HOLD of ``n_pages`` for ``slot`` (a chunked prefill's
        whole winnow need, mapped chunk by chunk later).  The caller must
        have checked the slot's shard free pages first — reserving past
        them is a bug."""
        if n_pages > self.shard_free_pages(self.shard_of(slot)):
            raise self._exhausted(
                f"cannot hold {n_pages} pages for slot {slot}: only "
                f"{self.shard_free_pages(self.shard_of(slot))} unheld pages "
                f"free on its shard", slot)
        self._held[slot] += n_pages

    def _shard_held(self, shard: int) -> int:
        lo = shard * self.slots_per_shard
        return int(self._held[lo:lo + self.slots_per_shard].sum())

    def _alloc_one(self, slot: int) -> int:
        sh = self.shard_of(slot)
        if self._held[slot] > 0:
            self._held[slot] -= 1          # consume the slot's own hold
        elif len(self._free[sh]) - self._shard_held(sh) <= 0:
            raise self._exhausted(
                f"page pool exhausted: {len(self._free[sh])} free pages on "
                f"shard {sh} all held for in-flight prefills (slot {slot} "
                "needs one more)", slot)
        if not self._free[sh]:
            raise self._exhausted(
                f"page pool exhausted: {self.pages_per_shard - 1} usable "
                f"pages on shard {sh}, all live (slot {slot} needs one "
                "more)", slot)
        p = self._free[sh].pop()
        assert self._owner[sh, p] == -1 and p != TRASH_PAGE
        self._owner[sh, p] = slot
        logical = int(self.n_mapped[slot])
        self.table[slot, logical] = p
        self.n_mapped[slot] += 1
        self.version += 1
        self._metrics.counter("page_pool_pages_mapped_total",
                              "physical pages mapped to slots").inc()
        if self._trace is not None:
            self._trace.emit("page_map", step=self._step(), slot=slot,
                             shard=sh, logical=logical, physical=int(p))
        return p

    def free_slot(self, slot: int) -> int:
        """Retire ``slot``: return its pages to its shard's free list (and
        drop any outstanding hold).  Returns the number of pages freed."""
        sh = self.shard_of(slot)
        n = int(self.n_mapped[slot])
        for j in range(n):
            p = int(self.table[slot, j])
            assert self._owner[sh, p] == slot
            self._owner[sh, p] = -1
            self._free[sh].append(p)
        self.table[slot, :] = TRASH_PAGE
        self.n_mapped[slot] = 0
        self._held[slot] = 0
        if n:
            self.version += 1
            self._metrics.counter("page_pool_pages_freed_total",
                                  "pages returned on retirement").inc(n)
        if self._trace is not None:
            self._trace.emit("page_free", step=self._step(), slot=slot,
                             shard=sh, n_pages=n)
        return n

    def grow(self, new_pages_per_shard: int) -> None:
        """Extend EVERY shard's block to ``new_pages_per_shard`` local
        pages (the device pool's page axis must stay evenly partitioned).
        Existing local indices stay valid — the page table is untouched —
        and the new pages join the back of each free list, so warm
        just-freed pages are still handed out first.  The caller grows the
        device-side pool arrays to match (see ServeEngine._grow_pool)."""
        old = self.pages_per_shard
        if new_pages_per_shard <= old:
            raise ValueError(f"grow to {new_pages_per_shard} <= current "
                             f"{old} pages per shard")
        fresh = list(range(new_pages_per_shard - 1, old - 1, -1))
        self._free = [fresh.copy() + f for f in self._free]
        self._owner = np.concatenate(
            [self._owner,
             np.full((self.n_shards, new_pages_per_shard - old), -1,
                     np.int64)], axis=1)
        self.pages_per_shard = new_pages_per_shard
        self.n_pages = new_pages_per_shard * self.n_shards

    # ------------------------------------------------------------------
    # Accounting / introspection
    # ------------------------------------------------------------------

    @property
    def live_pages(self) -> int:
        return (self.n_pages - self.n_shards
                - sum(len(f) for f in self._free))

    def shard_live_pages(self, shard: int) -> int:
        return self.pages_per_shard - 1 - len(self._free[shard])

    @property
    def free_pages(self) -> int:
        """Pages available to NEW claimants across all shards: free minus
        outstanding holds (admission gates compare against the candidate
        slot's ``shard_free_pages``; this global view is for reporting)."""
        return sum(len(f) for f in self._free) - int(self._held.sum())

    def shard_free_pages(self, shard: int) -> int:
        """Pages available to NEW claimants on ``shard`` — what the
        admission gate checks a prompt's winnow need against."""
        return len(self._free[shard]) - self._shard_held(shard)

    @property
    def held_pages(self) -> int:
        return int(self._held.sum())

    def live_bytes(self, bytes_per_page: int) -> int:
        return self.live_pages * bytes_per_page

    def reserved_bytes(self, bytes_per_page: int) -> int:
        return self.n_pages * bytes_per_page

    def shard_live_bytes(self, shard: int, bytes_per_page: int) -> int:
        return self.shard_live_pages(shard) * bytes_per_page

    def shard_reserved_bytes(self, shard: int, bytes_per_page: int) -> int:
        return self.pages_per_shard * bytes_per_page

    def check_consistent(self) -> None:
        """Assert the aliasing/accounting invariants (used by tests)."""
        live = self.table[self.table != TRASH_PAGE]
        assert TRASH_PAGE not in [p for f in self._free for p in f]
        assert (self._held >= 0).all()
        for sh in range(self.n_shards):
            lo = sh * self.slots_per_shard
            rows = self.table[lo:lo + self.slots_per_shard]
            sh_live = rows[rows != TRASH_PAGE]
            assert sh_live.size == len(set(sh_live.tolist())), \
                "page aliased by 2 slots"
            assert len(self._free[sh]) + sh_live.size == \
                self.pages_per_shard - 1
            assert self._shard_held(sh) <= len(self._free[sh]), \
                "holds exceed free pages"
        assert live.size == self.live_pages
        for slot in range(self.n_slots):
            sh = self.shard_of(slot)
            n = int(self.n_mapped[slot])
            assert (self.table[slot, :n] != TRASH_PAGE).all()
            assert (self.table[slot, n:] == TRASH_PAGE).all()
            assert (self._owner[sh, self.table[slot, :n]] == slot).all()
