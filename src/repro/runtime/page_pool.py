"""Host-side page allocator for the paged sparse KV cache.

The device keeps one shared pool of fixed-size pages per layer (see
``repro.core.paged_cache``); this module owns the *mapping*: which physical
page backs which (slot, logical-page) pair.  All allocator state is plain
numpy on the host — the scheduler already runs there, and the page table is
shipped to the device as a tiny ``[n_slots, pages_per_seq]`` int32 operand
each step.

Invariants (enforced, and property-tested in tests/test_page_pool.py):

  * physical page 0 is the TRASH page: it is never allocated, and every
    unmapped page-table entry points at it.  Clamped garbage writes (the
    hybrid cache's pos < buffer eviction trick) and gathers of not-yet-live
    logical pages all land there, where validity masks hide them;
  * a physical page != 0 is owned by at most one slot at a time — two live
    sequences can never alias storage;
  * ``free_slot`` returns pages to the free list immediately, so a request
    backfilled into the slot on the same engine step reuses them;
  * exhaustion raises ``PagePoolExhausted`` (a clean, catchable error)
    without corrupting allocator state;
  * reservations (``reserve``): a chunked prefill maps its pages one chunk
    at a time, so admission places a HOLD for the prompt's whole winnow
    need — the slot's own allocations consume the hold first, and no other
    slot may dip into held stock.  This closes the check-without-reserve
    race where a decoding slot's growth (or a same-step second admission)
    starves an already-admitted in-flight prefill.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.paged_cache import TRASH_PAGE  # single source of truth


class PagePoolExhausted(RuntimeError):
    """No free physical pages left — the pool is over-committed."""


class PagePool:
    """Free-list allocator over ``n_pages`` physical pages.

    ``table[slot, j]`` is the physical page backing logical page ``j`` of
    ``slot`` (0 = unmapped / trash).  Logical pages are mapped densely from
    0 upward — the hybrid cache writes winnowed tokens in position order, so
    a sequence's mapping only ever grows at the end (until the slot is
    freed wholesale on retirement).
    """

    def __init__(self, n_pages: int, pages_per_seq: int, n_slots: int,
                 page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved as trash)")
        self.n_pages = n_pages
        self.pages_per_seq = pages_per_seq
        self.n_slots = n_slots
        self.page_size = page_size
        # LIFO free list: a just-retired sequence's pages are the next ones
        # handed out (warm reuse)
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self.table = np.full((n_slots, pages_per_seq), TRASH_PAGE, np.int32)
        self.n_mapped = np.zeros((n_slots,), np.int64)
        self._owner = np.full((n_pages,), -1, np.int64)   # -1 = free/trash
        self._held = np.zeros((n_slots,), np.int64)       # outstanding holds
        # dirty counter: bumped on every ``table`` mutation so the engine
        # can cache device uploads of table prefixes and re-ship only when
        # the mapping actually changed (most decode steps map nothing)
        self.version = 0

    # ------------------------------------------------------------------
    # Allocation
    # ------------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Logical pages needed to hold ``n_tokens`` sparse tokens."""
        return -(-max(int(n_tokens), 0) // self.page_size)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot``'s mapping to cover ``n_tokens`` sparse tokens."""
        need = self.pages_for(n_tokens)
        if need > self.pages_per_seq:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens need {need} pages "
                f"> pages_per_seq={self.pages_per_seq}")
        while self.n_mapped[slot] < need:
            self._alloc_one(slot)

    def reserve(self, slot: int, n_pages: int) -> None:
        """Place a HOLD of ``n_pages`` for ``slot`` (a chunked prefill's
        whole winnow need, mapped chunk by chunk later).  The caller must
        have checked ``free_pages`` first — reserving past it is a bug."""
        if n_pages > self.free_pages:
            raise PagePoolExhausted(
                f"cannot hold {n_pages} pages for slot {slot}: only "
                f"{self.free_pages} unheld pages free")
        self._held[slot] += n_pages

    def _alloc_one(self, slot: int) -> int:
        if self._held[slot] > 0:
            self._held[slot] -= 1          # consume the slot's own hold
        elif len(self._free) - int(self._held.sum()) <= 0:
            raise PagePoolExhausted(
                f"page pool exhausted: {len(self._free)} free pages all "
                f"held for in-flight prefills (slot {slot} needs one more)")
        if not self._free:
            raise PagePoolExhausted(
                f"page pool exhausted: {self.n_pages - 1} usable pages, "
                f"all live (slot {slot} needs one more)")
        p = self._free.pop()
        assert self._owner[p] == -1 and p != TRASH_PAGE
        self._owner[p] = slot
        self.table[slot, self.n_mapped[slot]] = p
        self.n_mapped[slot] += 1
        self.version += 1
        return p

    def free_slot(self, slot: int) -> int:
        """Retire ``slot``: return its pages to the free list (and drop any
        outstanding hold).  Returns the number of pages freed."""
        n = int(self.n_mapped[slot])
        for j in range(n):
            p = int(self.table[slot, j])
            assert self._owner[p] == slot
            self._owner[p] = -1
            self._free.append(p)
        self.table[slot, :] = TRASH_PAGE
        self.n_mapped[slot] = 0
        self._held[slot] = 0
        if n:
            self.version += 1
        return n

    # ------------------------------------------------------------------
    # Accounting / introspection
    # ------------------------------------------------------------------

    @property
    def live_pages(self) -> int:
        return self.n_pages - 1 - len(self._free)

    @property
    def free_pages(self) -> int:
        """Pages available to NEW claimants: free minus outstanding holds
        (the admission gate compares prompt needs against this)."""
        return len(self._free) - int(self._held.sum())

    @property
    def held_pages(self) -> int:
        return int(self._held.sum())

    def live_bytes(self, bytes_per_page: int) -> int:
        return self.live_pages * bytes_per_page

    def reserved_bytes(self, bytes_per_page: int) -> int:
        return self.n_pages * bytes_per_page

    def check_consistent(self) -> None:
        """Assert the aliasing/accounting invariants (used by tests)."""
        live = self.table[self.table != TRASH_PAGE]
        assert live.size == len(set(live.tolist())), "page aliased by 2 slots"
        assert TRASH_PAGE not in self._free
        assert len(self._free) + live.size == self.n_pages - 1
        assert (self._held >= 0).all()
        assert int(self._held.sum()) <= len(self._free), \
            "holds exceed free pages"
        for slot in range(self.n_slots):
            n = int(self.n_mapped[slot])
            assert (self.table[slot, :n] != TRASH_PAGE).all()
            assert (self.table[slot, n:] == TRASH_PAGE).all()
            assert (self._owner[self.table[slot, :n]] == slot).all()
