"""Fault-tolerance utilities: preemption handling + straggler watchdog.

* ``PreemptionHandler`` — installs a SIGTERM handler (the preemption signal
  on TPU/GKE); the training loop checkpoints and exits cleanly when
  triggered.  Idempotent install, restores previous handler on close.
* ``StepWatchdog`` — EMA-based step-time anomaly detector.  On a real
  cluster a straggling host shows up as a slow *global* step (collectives
  synchronise); the watchdog flags steps slower than ``threshold×`` the EMA
  so the operator (or an external policy) can checkpoint-and-requeue.
"""
from __future__ import annotations

import signal
import threading
from typing import List, Optional, Tuple


class PreemptionHandler:
    def __init__(self, sig=signal.SIGTERM):
        self._triggered = threading.Event()
        self._sig = sig
        self._prev = None
        try:
            self._prev = signal.signal(sig, self._handle)
            self.installed = True
        except ValueError:        # non-main thread (tests)
            self.installed = False

    def _handle(self, signum, frame):
        self._triggered.set()

    @property
    def triggered(self) -> bool:
        return self._triggered.is_set()

    def trigger(self) -> None:    # for tests / manual drain
        self._triggered.set()

    def close(self) -> None:
        if self.installed and self._prev is not None:
            signal.signal(self._sig, self._prev)


class StepWatchdog:
    """Flags straggler steps: duration > threshold × EMA(duration)."""

    def __init__(self, threshold: float = 3.0, ema_decay: float = 0.9,
                 warmup_steps: int = 3):
        self.threshold = threshold
        self.decay = ema_decay
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.n = 0
        self.stragglers: List[Tuple[int, float, float]] = []  # (step, dt, ema)

    def record(self, step: int, duration: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = duration
            return False
        is_straggler = (self.n > self.warmup and
                        duration > self.threshold * self.ema)
        if is_straggler:
            self.stragglers.append((step, duration, self.ema))
        else:
            # stragglers don't poison the EMA
            self.ema = self.decay * self.ema + (1 - self.decay) * duration
        return is_straggler
