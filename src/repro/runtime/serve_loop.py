"""Serving runtime: jitted prefill/decode steps + a batched generation
session with SWAN compression plumbed through.

``pos`` is a traced scalar so one compiled decode executable serves every
step; caches are donated (in-place buffer reuse).  The SWAN runtime knobs
(k_key / k_value) are baked per ``SwanConfig`` — changing them re-jits only
the (cheap) decode step, never touches weights (paper's runtime tunability).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import projections as proj_mod
from repro.core.analytical import model_cache_footprint
from repro.models import get_model, swan_applicable
from repro.obs.metrics import MetricsRegistry, NULL_REGISTRY
from repro.runtime.sampling import sample_token

# wall-clock step-call buckets (ms).  These time the HOST call around the
# jitted step — async dispatch cost for a warm executable, full trace +
# compile time on a cache miss — so re-jits show up as outliers in the top
# buckets.  Device-inclusive timing needs an explicit block_until_ready
# (see repro.obs.trace.span).
STEP_MS_BUCKETS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                   128.0, 256.0, 512.0, 1024.0)

Params = Dict[str, Any]


def calibrate_swan(api, cfg, params, calib_batch) -> Params:
    """Offline calibration (paper §4.1): capture activations, joint SVD."""
    q, k, v, wo = api.collect_qkv(params, cfg, calib_batch)
    return proj_mod.compute_projections((q, k, v), wo, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.d_head)


def serve_cache_report(cfg, swan, batch: int, max_seq: int) -> Dict[str, Any]:
    """Physical cache accounting (paper Eq. 1) shared by ServeSession and
    ServeEngine.  ``swan`` None -> dense baseline.

    ``bytes`` here is the worst-case (slab) layout: every slot reserves
    max_seq rows up front.  The paged engine overrides ``reserved_bytes``/
    ``live_bytes`` with pool-granular numbers (ServeEngine.cache_report)."""
    if swan is None:
        fp = model_cache_footprint(cfg, _DenseLike(cfg.d_head), batch, max_seq)
        return {"mode": "dense", "bytes": fp.dense_bytes}
    fp = model_cache_footprint(cfg, swan, batch, max_seq)
    return {"mode": f"swan[{swan.mode}]", "bytes": fp.swan_bytes,
            "dense_bytes": fp.dense_bytes, "saving": fp.saving}


class ServeSession:
    """Batched autoregressive generation with optional SWAN cache."""

    def __init__(self, cfg, params, swan=None, projections=None,
                 max_seq: int = 4096, batch: int = 1, jit: bool = True,
                 metrics=False):
        # metrics: True -> fresh MetricsRegistry, an existing registry to
        # share one across sessions, False (default) -> no-op instruments.
        if isinstance(metrics, MetricsRegistry):
            self.metrics = metrics
        else:
            self.metrics = MetricsRegistry() if metrics else NULL_REGISTRY
        self.cfg = cfg
        self.api = get_model(cfg)
        self.swan = swan if (swan and swan.enabled and swan_applicable(cfg)) else None
        self.projections = projections
        self.max_seq = max_seq
        self.batch = batch
        if self.swan is not None:
            self.swan.validate(cfg.d_head)
            if projections is None:
                raise ValueError("SWAN enabled but no projections given — "
                                 "run calibrate_swan first")
        self.params = params
        self.state = self.api.init_serve_state(cfg, self.swan, batch, max_seq)
        sw, pj = self.swan, self.projections

        def prefill_fn(p, batch_in, state):
            return self.api.prefill(p, cfg, batch_in, state, sw, pj)

        def decode_fn(p, token, pos, state):
            return self.api.decode_step(p, cfg, token, pos, state, sw, pj)

        if jit:
            self._prefill = jax.jit(prefill_fn, donate_argnums=(2,))
            self._decode = jax.jit(decode_fn, donate_argnums=(3,))
        else:
            self._prefill, self._decode = prefill_fn, decode_fn
        self.pos = 0

    def prefill(self, batch_in: Params) -> jnp.ndarray:
        t0 = time.perf_counter()
        logits, self.state = self._prefill(self.params, batch_in, self.state)
        self.metrics.counter("session_prefill_total",
                             "prefill calls").inc()
        self.metrics.histogram(
            "session_prefill_call_ms", STEP_MS_BUCKETS,
            "host wall-clock of the prefill call (compiles show as "
            "outliers)").observe((time.perf_counter() - t0) * 1e3)
        self.pos = batch_in["tokens"].shape[1]
        return logits[:, -1]

    def decode(self, token: jnp.ndarray) -> jnp.ndarray:
        t0 = time.perf_counter()
        logits, self.state = self._decode(self.params, token,
                                          jnp.asarray(self.pos, jnp.int32),
                                          self.state)
        self.metrics.counter("session_decode_total",
                             "decode step calls").inc()
        self.metrics.histogram(
            "session_decode_call_ms", STEP_MS_BUCKETS,
            "host wall-clock of the decode call (compiles show as "
            "outliers)").observe((time.perf_counter() - t0) * 1e3)
        self.pos += 1
        return logits

    def generate(self, batch_in: Params, n_tokens: int,
                 temperature: float = 0.0, seed: int = 0) -> jnp.ndarray:
        """Greedy (or sampled) generation; returns [B, n_tokens].

        Key schedule: ``key_i = split(...split(PRNGKey(seed))...)[1]`` — the
        root key is only ever split, never consumed.  (The previous code
        sampled the prefill token WITH the root key and then split that same
        key to derive every later sample key — textbook use-then-split key
        reuse; pinned by tests/test_serve_session.py.)
        """
        logits = self.prefill(batch_in)
        key = jax.random.PRNGKey(seed)
        outs = []
        key, sub = jax.random.split(key)
        tok = sample_token(logits, temperature, sub)
        tok_ctr = self.metrics.counter("session_tokens_generated_total",
                                       "tokens sampled by generate()")
        for i in range(n_tokens):
            outs.append(tok)
            tok_ctr.inc(self.batch)
            if i == n_tokens - 1:
                break
            logits = self.decode(tok)
            key, sub = jax.random.split(key)
            tok = sample_token(logits, temperature, sub)
        return jnp.stack(outs, axis=1)

    def cache_report(self) -> Dict[str, Any]:
        """Physical cache accounting (paper Eq. 1 applied to this model)."""
        return serve_cache_report(self.cfg, self.swan, self.batch,
                                  self.max_seq)


class _DenseLike:
    def __init__(self, d_head):
        self.k_max = d_head
        self.buffer = 0
        self.quantize = False
