"""Deterministic data pipeline: synthetic token streams + binary token files.

* ``SyntheticStream`` — hash-based deterministic tokens with local structure
  (Markov-ish mixing) so that tiny LMs can actually learn something; fully
  reproducible given (seed, step), which makes checkpoint-resume bit-exact
  without saving data state.
* ``FileStream`` — memory-mapped binary token shards with per-host disjoint
  striding, epoch reshuffling, background prefetch thread.

Both yield {"tokens": [B, S+1]} host arrays; the train step slices
inputs/targets.  Per-host sharding: host h of H reads rows where
(row % H == h) — disjoint by construction (test-enforced).
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticStream:
    """Deterministic synthetic LM data.

    Sequences mix three mechanisms (probabilities ``markov/copy/noise``):
      * a vocabulary-walk with a fixed stochastic matrix seeded from
        ``seed`` (local structure — learnable from the previous token),
      * a *long-range copy*: token[t] = token[t - copy_period] — only
        learnable by attending ``copy_period`` back (induction-head style),
        which is what makes KV-cache compression quality measurable: the
        copied-from tokens live OUTSIDE a small recency buffer,
      * uniform noise.
    Fully reproducible given (seed, step): checkpoint resume is bit-exact
    without data-state snapshots.
    """

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1,
                 markov: float = 0.45, copy: float = 0.45,
                 copy_period: int = 24):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.markov = markov
        self.copy = copy
        self.copy_period = copy_period
        base = np.random.default_rng(seed)
        self._next_tok = base.integers(0, vocab_size, size=vocab_size)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.n_hosts + self.host_id)
        B, S, V = self.batch, self.seq + 1, self.vocab
        P = self.copy_period
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        u = rng.random((B, S))
        rand = rng.integers(0, V, (B, S))
        for t in range(1, S):
            out = np.where(u[:, t] < self.markov,
                           self._next_tok[toks[:, t - 1]], rand[:, t])
            if t >= P:
                use_copy = (u[:, t] >= self.markov) & \
                    (u[:, t] < self.markov + self.copy)
                out = np.where(use_copy, toks[:, t - P], out)
            toks[:, t] = out
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileStream:
    """Binary uint16/uint32 token shards, memory-mapped, host-striped."""

    def __init__(self, path: str, vocab_size: int, batch: int, seq: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1,
                 dtype=np.uint16, prefetch: int = 2):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab_size
        self.batch, self.seq = batch, seq
        self.seed, self.host_id, self.n_hosts = seed, host_id, n_hosts
        self.n_rows = len(self.tokens) // (seq + 1)
        if self.n_rows < batch:
            raise ValueError(f"file {path} too small: {self.n_rows} rows")
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        epoch = step * self.batch * self.n_hosts // self.n_rows
        rng = np.random.default_rng(self.seed + epoch)
        perm = rng.permutation(self.n_rows)
        base = (step * self.batch * self.n_hosts) % self.n_rows
        rows = perm[(base + self.host_id * self.batch +
                     np.arange(self.batch)) % self.n_rows]
        S = self.seq + 1
        out = np.stack([self.tokens[r * S:(r + 1) * S] for r in rows])
        return {"tokens": np.minimum(out.astype(np.int32), self.vocab - 1)}

    def _worker(self, start_step: int):
        step = start_step
        while True:
            self._q.put(self.batch_at(step))
            step += 1

    def prefetching_iter(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        self._thread = threading.Thread(target=self._worker,
                                        args=(start_step,), daemon=True)
        self._thread.start()
        while True:
            yield self._q.get()


def write_token_file(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.asarray(tokens, dtype).tofile(path)
