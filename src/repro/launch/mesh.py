"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  The dry-run sets XLA_FLAGS before any jax import to get 512
placeholder host devices; smoke tests and benchmarks see the real device
count (1 CPU here).
"""
from __future__ import annotations

import jax

try:   # newer jax; older releases have neither AxisType nor axis_types
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _mk(shape, axes):
    if not hasattr(jax, "make_mesh"):   # pre-0.4.35: build the Mesh directly
        from jax.experimental import mesh_utils
        from jax.sharding import Mesh
        return Mesh(mesh_utils.create_device_mesh(shape), axes)
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 4))."""
    return _mk(tuple(shape), tuple(axes))


def make_serve_mesh(data_parallel: int):
    """1-axis ('data',) mesh for the sharded serve engine
    (repro.runtime.serve_engine with mesh=): the engine's batched state —
    and the paged pool's page axis — shard over 'data'; model weights are
    replicated across it."""
    return _mk((int(data_parallel),), ("data",))
