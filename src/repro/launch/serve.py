"""Serving launcher: calibrate SWAN on a checkpoint (or fresh weights) and
run batched generation.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --swan --k 8 --buffer 16 --tokens 32
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import SwanConfig, get_config, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model, swan_applicable
from repro.runtime.serve_loop import ServeSession, calibrate_swan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", help="restore params from a checkpoint")
    ap.add_argument("--swan", action="store_true")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--buffer", type=int, default=128)
    ap.add_argument("--mode", default="topk", choices=["topk", "truncate"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        step = ck.latest_step()
        if step is not None:
            state = ck.restore(step, {"params": params})
            params = state["params"]
            print(f"restored checkpoint step {step}")

    swan = projections = None
    if args.swan:
        if not swan_applicable(cfg):
            raise SystemExit(f"SWAN inapplicable to {cfg.name} "
                             "(see DESIGN.md §Arch-applicability)")
        b = min(args.buffer, args.max_seq // 4)
        swan = SwanConfig(k_max=args.k or cfg.d_head // 2, buffer=b,
                          mode=args.mode, quantize=args.quantize)
        projections = calibrate_swan(api, cfg, params,
                                     make_batch(cfg, 4, 64, seed=3))
        params = api.absorb(params, cfg, projections)
        print(f"SWAN: k_max={swan.k_max}/{cfg.d_head} buffer={b} "
              f"mode={swan.mode} int8={swan.quantize}")

    sess = ServeSession(cfg, params, swan=swan, projections=projections,
                        max_seq=args.max_seq, batch=args.batch)
    prompt = make_batch(cfg, args.batch, args.prompt_len, seed=11)
    out = sess.generate(prompt, args.tokens, temperature=args.temperature)
    for i in range(min(args.batch, 2)):
        print(f"seq {i}: {out[i].tolist()}")
    rep = sess.cache_report()
    extra = f" ({rep['saving']:.0%} vs dense)" if "saving" in rep else ""
    print(f"cache [{rep['mode']}]: {rep['bytes'] / 1e6:.2f} MB{extra}")


if __name__ == "__main__":
    main()
