"""Serving launcher: calibrate SWAN on a checkpoint (or fresh weights) and
run batched generation.

Lockstep batch (one shared position, the paper's benchmark setting):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --swan --k 8 --buffer 16 --tokens 32

Continuous batching (request queue + slot scheduler, mixed prompt lengths
and per-request SWAN k — see repro.runtime.serve_engine):

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --swan --k 8 --buffer 16 --tokens 32 --engine --requests 8 --mixed-k
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import SwanConfig, get_config, get_smoke_config
from repro.launch.io import make_batch
from repro.launch.mesh import make_mesh, make_serve_mesh
from repro.models import get_model, swan_applicable
from repro.obs import EventTrace
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import ServeSession, calibrate_swan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", help="restore params from a checkpoint")
    ap.add_argument("--swan", action="store_true")
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--buffer", type=int, default=128)
    ap.add_argument("--mode", default="topk", choices=["topk", "truncate"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous batching instead of lockstep")
    ap.add_argument("--requests", type=int, default=None,
                    help="engine: number of requests (default: --batch * 2)")
    ap.add_argument("--mixed-k", action="store_true",
                    help="engine: cycle per-request SWAN k overrides")
    ap.add_argument("--paged", action="store_true",
                    help="engine+swan: paged sparse cache — memory follows "
                         "live tokens (repro.core.paged_cache)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged: token positions per page")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged: physical pages in the shared pool "
                         "(default: full reservation; smaller over-commits)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="engine: chunked prefill — split prompts into "
                         "power-of-two chunks with bounded prefill work "
                         "per engine step, so long admissions never stall "
                         "decoding (default: monolithic admission)")
    ap.add_argument("--prefill-slots", type=int, default=1,
                    help="engine: batched concurrent prefill — up to P "
                         "in-flight prefills advance per step, packed into "
                         "one multi-slot chunk dispatch (cuts TTFT under "
                         "admission bursts; requires --prefill-chunk)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="engine: per-step prefill token budget "
                         "round-robined across in-flight prefills "
                         "(default: prefill-slots * prefill-chunk)")
    ap.add_argument("--data-parallel", type=int, default=None,
                    help="engine: shard slots, caches and the paged pool "
                         "over a ('data',) mesh of this many devices "
                         "(shard-local slot scheduler; n_slots must "
                         "divide)")
    ap.add_argument("--mesh-shape", default=None,
                    help="engine: explicit mesh as 'AXIS=N,AXIS=N' (must "
                         "include a data axis), e.g. 'data=4' or "
                         "'data=4,model=2' — overrides --data-parallel")
    ap.add_argument("--pool-grow", action="store_true",
                    help="paged: grow the device pool (2x pages, copy, "
                         "extend free lists) when it runs dry instead of "
                         "holding admissions")
    ap.add_argument("--use-pallas", action="store_true", default=None,
                    help="engine: force the Pallas kernel-backed decode/"
                         "chunk attention read (default: auto — compiled "
                         "kernels on TPU, pure-JAX elsewhere; forcing on "
                         "CPU runs the kernels under the interpreter)")
    ap.add_argument("--warmup", action="store_true",
                    help="engine: pre-compile the FULL executable family "
                         "before the first request (repro.runtime.warmup) "
                         "— no mid-serve JIT cliffs; prints the warmup "
                         "report summary")
    ap.add_argument("--max-prompt-len", type=int, default=None,
                    help="engine: trim the warmed prefix family to prompts "
                         "of at most this many tokens (default max-seq); "
                         "longer prompts still serve — their buckets just "
                         "compile lazily")
    ap.add_argument("--async-fetch", action="store_true",
                    help="engine: overlap host scheduling with the decode "
                         "token transfer (copy_to_host_async at dispatch, "
                         "resolved at the next step; token-identical)")
    ap.add_argument("--compilation-cache-dir", default=None,
                    help="persist JAX's compilation cache here so engine "
                         "restarts reload compiled executables from disk "
                         "instead of recompiling the family")
    ap.add_argument("--admission", default="fifo", choices=["fifo", "srf"],
                    help="engine: admission policy — fifo, or srf "
                         "(shortest-remaining-first: bounds TTFT when the "
                         "queue exceeds prefill capacity)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot here — "
                         "Prometheus text if the path ends in .prom/.txt, "
                         "JSON otherwise (repro.obs.metrics)")
    ap.add_argument("--trace-out", default=None,
                    help="engine: stream a structured JSONL event trace "
                         "(admissions, dispatches, first tokens, page "
                         "map/free, ...) to this path (repro.obs.trace)")
    ap.add_argument("--profile-steps", type=int, default=None,
                    help="engine: capture one jax.profiler trace spanning "
                         "this many engine steps into --profile-dir")
    ap.add_argument("--profile-dir", default="profile",
                    help="engine: jax.profiler trace output directory")
    args = ap.parse_args()
    if args.prefill_chunk and not args.engine:
        raise SystemExit("--prefill-chunk requires --engine")
    if ((args.prefill_slots > 1 or args.prefill_budget is not None)
            and not args.prefill_chunk):
        raise SystemExit("--prefill-slots/--prefill-budget require "
                         "--prefill-chunk")
    if args.paged and not (args.engine and args.swan):
        raise SystemExit("--paged requires --engine and --swan")
    if (args.data_parallel or args.mesh_shape) and not args.engine:
        raise SystemExit("--data-parallel/--mesh-shape require --engine")
    if args.pool_grow and not args.paged:
        raise SystemExit("--pool-grow requires --paged")
    if (args.trace_out or args.profile_steps) and not args.engine:
        raise SystemExit("--trace-out/--profile-steps require --engine")
    if args.use_pallas and not (args.engine and args.swan):
        raise SystemExit("--use-pallas requires --engine and --swan "
                         "(the kernels back the SWAN serve read path)")
    if (args.warmup or args.async_fetch) and not args.engine:
        raise SystemExit("--warmup/--async-fetch require --engine")
    if args.compilation_cache_dir:
        # before any compile happens, so the whole family lands on disk
        from repro.runtime.warmup import enable_compilation_cache
        enable_compilation_cache(args.compilation_cache_dir)
        print(f"compilation cache -> {args.compilation_cache_dir}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        step = ck.latest_step()
        if step is not None:
            state = ck.restore(step, {"params": params})
            params = state["params"]
            print(f"restored checkpoint step {step}")

    swan = projections = None
    if args.swan:
        if not swan_applicable(cfg):
            raise SystemExit(f"SWAN inapplicable to {cfg.name} "
                             "(see DESIGN.md §Arch-applicability)")
        b = min(args.buffer, args.max_seq // 4)
        swan = SwanConfig(k_max=args.k or cfg.d_head // 2, buffer=b,
                          mode=args.mode, quantize=args.quantize)
        projections = calibrate_swan(api, cfg, params,
                                     make_batch(cfg, 4, 64, seed=3))
        params = api.absorb(params, cfg, projections)
        print(f"SWAN: k_max={swan.k_max}/{cfg.d_head} buffer={b} "
              f"mode={swan.mode} int8={swan.quantize}")

    if args.engine:
        _run_engine(cfg, params, swan, projections, args)
        return

    sess = ServeSession(cfg, params, swan=swan, projections=projections,
                        max_seq=args.max_seq, batch=args.batch,
                        metrics=bool(args.metrics_out))
    prompt = make_batch(cfg, args.batch, args.prompt_len, seed=11)
    out = sess.generate(prompt, args.tokens, temperature=args.temperature)
    for i in range(min(args.batch, 2)):
        print(f"seq {i}: {out[i].tolist()}")
    rep = sess.cache_report()
    extra = f" ({rep['saving']:.0%} vs dense)" if "saving" in rep else ""
    print(f"cache [{rep['mode']}]: {rep['bytes'] / 1e6:.2f} MB{extra}")
    _write_metrics(sess.metrics, args.metrics_out)


def _write_metrics(registry, path):
    """Dump a registry snapshot: Prometheus text for .prom/.txt paths,
    JSON otherwise.  No-op when path is None."""
    if not path:
        return
    if path.endswith((".prom", ".txt")):
        body = registry.to_prometheus()
    else:
        body = registry.to_json(indent=2)
    with open(path, "w") as fh:
        fh.write(body)
    print(f"metrics -> {path}")


def _serve_mesh(args):
    """Build the engine mesh from --mesh-shape / --data-parallel (None =
    single device)."""
    if args.mesh_shape:
        pairs = [kv.split("=") for kv in args.mesh_shape.split(",")]
        return make_mesh([int(n) for _, n in pairs], [ax for ax, _ in pairs])
    if args.data_parallel:
        return make_serve_mesh(args.data_parallel)
    return None


def _run_engine(cfg, params, swan, projections, args):
    mesh = _serve_mesh(args)
    trace = EventTrace(args.trace_out, keep=False) if args.trace_out else None
    eng = ServeEngine(cfg, params, swan=swan, projections=projections,
                      max_seq=args.max_seq, n_slots=args.batch,
                      paged=args.paged, page_size=args.page_size,
                      n_pages=args.pool_pages,
                      prefill_chunk=args.prefill_chunk,
                      prefill_slots=args.prefill_slots,
                      prefill_budget=args.prefill_budget,
                      mesh=mesh, pool_grow=args.pool_grow,
                      admission=args.admission, trace=trace,
                      use_pallas=args.use_pallas,
                      async_fetch=args.async_fetch)
    if args.warmup:
        rep = eng.warmup(max_prompt_len=args.max_prompt_len)
        print(f"warmup: {rep['census']['total']} executables, "
              f"{rep['compiles']} compiles in {rep['warmup_ms']:.0f} ms "
              f"({ {k: v['compiles'] for k, v in rep['by_kind'].items()} })")
    if args.profile_steps:
        eng.profile_steps(args.profile_steps, args.profile_dir)
    if mesh is not None:
        print(f"mesh: {dict(mesh.shape)} — {eng.dp} shards x "
              f"{eng.n_local} slots")
    n_req = args.requests or args.batch * 2
    k_cycle = ([None] if (swan is None or not args.mixed_k)
               else [swan.k_max, max(swan.k_max // 2, 1),
                     max(swan.k_max // 4, 1)])
    reqs = []
    for i in range(n_req):
        plen = max(4, args.prompt_len - 3 * (i % 4))     # mixed prompt lengths
        toks = make_batch(cfg, 1, plen, seed=100 + i)["tokens"][0]
        reqs.append(Request(
            uid=f"req{i}", tokens=[int(t) for t in toks],
            max_new_tokens=args.tokens, temperature=args.temperature,
            seed=i, k=k_cycle[i % len(k_cycle)]))
    t0 = time.perf_counter()
    comps = eng.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in comps)
    for c in comps[:2]:
        print(f"{c.uid} (prompt {c.prompt_len}, k={c.k}, "
              f"steps {c.admitted_step}->{c.finished_step}): {c.tokens}")
    rep = eng.cache_report()
    extra = f" ({rep['saving']:.0%} vs dense)" if "saving" in rep else ""
    print(f"engine: {len(comps)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s, {eng.step_count} steps, "
          f"decode executables: {eng.decode_cache_size}, "
          f"prefill executables: {eng.prefill_cache_size})")
    print(f"cache [{rep['mode']}]: {rep['bytes'] / 1e6:.2f} MB{extra}")
    if args.paged:
        print(f"paged: reserved {rep['reserved_bytes'] / 1e6:.2f} MB over "
              f"{rep['n_pages']} pages ({rep['page_size']} tok/page); "
              f"live now {rep['live_pages']} pages / "
              f"{rep['live_bytes'] / 1e6:.2f} MB "
              f"(slab layout would hold {rep['slab_bytes'] / 1e6:.2f} MB)")
    ttft = eng.metrics.get("serve_ttft_steps")
    if ttft is not None and ttft.count:
        print(f"ttft: p50 ~{ttft.quantile(0.5):.0f} steps, "
              f"p99 ~{ttft.quantile(0.99):.0f} steps (bucket-resolution)")
    _write_metrics(eng.metrics, args.metrics_out)
    if trace is not None:
        trace.close()
        print(f"trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
