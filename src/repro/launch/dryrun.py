"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell and extract roofline terms from the compiled artifact.

MUST be run as a script/module (``python -m repro.launch.dryrun``): the
XLA_FLAGS line below executes before any other import so jax sees 512
placeholder host devices.  Do NOT import this module from code that already
initialised jax with a different device count.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k [--multi-pod] [--swan]
  python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.hlo import analyze_hlo                    # noqa: E402
from repro.analysis.roofline import roofline_report           # noqa: E402
from repro.configs import (SHAPES, SwanConfig, get_config,    # noqa: E402
                           shape_applicable)
from repro.configs.base import OptimizerConfig                # noqa: E402
from repro.launch.io import decode_input_specs, train_input_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.models import get_model, swan_applicable           # noqa: E402
from repro.optim.adamw import init_opt_state                  # noqa: E402
from repro.runtime.train_loop import make_train_step          # noqa: E402
from repro.sharding.api import use_rules                      # noqa: E402
from repro.sharding.serve_specs import (batch_pspecs,         # noqa: E402
                                        sanitize_tree,
                                        serve_state_pspecs)
from repro.sharding.specs import activation_rules, params_pspecs  # noqa: E402


def _shardings(tree_specs, mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), tree_specs)


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def default_swan(cfg, mode: str = "topk", quantize: bool = False) -> SwanConfig:
    """Paper-faithful default: 50% retention, 128-token buffer (Fig. 2b)."""
    return SwanConfig(k_max=cfg.d_head // 2, buffer=128, mode=mode,
                      quantize=quantize)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               swan_on: bool, swan_mode: str = "topk",
               swan_quantize: bool = False,
               overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower + compile one cell; return the record for EXPERIMENTS.md."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "swan": swan_on, "status": "skipped", "reason": reason}
    if swan_on and not swan_applicable(cfg):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "swan": swan_on, "status": "skipped",
                "reason": "SWAN inapplicable (no KV cache)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    api = get_model(cfg)
    rules = activation_rules(cfg, mesh)
    swan = default_swan(cfg, swan_mode, swan_quantize) if swan_on else None
    t0 = time.monotonic()

    params_abs = api.abstract_params(cfg)
    p_specs = sanitize_tree(params_pspecs(params_abs, cfg, mesh), params_abs, mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, OptimizerConfig(), cfg.grad_accum)
        opt_abs = jax.eval_shape(
            lambda p: init_opt_state(p, OptimizerConfig()), params_abs)
        o_specs = {"m": p_specs, "v": p_specs, "step": P()}
        batch_abs = train_input_specs(cfg, shape.global_batch, shape.seq_len)
        b_specs = batch_pspecs(batch_abs, mesh)
        with use_rules(rules):
            lowered = jax.jit(step, donate_argnums=(0, 1), in_shardings=(
                _shardings(p_specs, mesh), _shardings(o_specs, mesh),
                _shardings(b_specs, mesh))).lower(params_abs, opt_abs, batch_abs)
    else:
        cache_len = shape.seq_len + cfg.n_prefix_tokens   # vlm prefix rows
        state_abs = jax.eval_shape(
            lambda: api.init_serve_state(cfg, swan, shape.global_batch,
                                         cache_len))
        s_specs = serve_state_pspecs(state_abs, mesh)
        proj_abs = None
        if swan_on:
            n_proj = _n_proj_layers(cfg)
            proj_abs = {"p_qk": jax.ShapeDtypeStruct(
                (n_proj, cfg.n_kv_heads, cfg.d_head, cfg.d_head), jnp.float32)}
        if shape.kind == "prefill":
            batch_abs = train_input_specs(cfg, shape.global_batch, shape.seq_len)
            b_specs = batch_pspecs(batch_abs, mesh)

            def fn(p, batch, state, proj):
                return api.prefill(p, cfg, batch, state, swan, proj)

            with use_rules(rules):
                lowered = jax.jit(fn, donate_argnums=(2,), in_shardings=(
                    _shardings(p_specs, mesh), _shardings(b_specs, mesh),
                    _shardings(s_specs, mesh),
                    _shardings(_abstract_specs(proj_abs), mesh),
                )).lower(params_abs, batch_abs, state_abs, proj_abs)
        else:  # decode
            tok_abs = decode_input_specs(cfg, shape.global_batch)["token"]
            tok_spec = batch_pspecs({"t": tok_abs}, mesh)["t"]
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

            def fn(p, token, pos, state, proj):
                return api.decode_step(p, cfg, token, pos, state, swan, proj)

            with use_rules(rules):
                lowered = jax.jit(fn, donate_argnums=(3,), in_shardings=(
                    _shardings(p_specs, mesh),
                    NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
                    _shardings(s_specs, mesh),
                    _shardings(_abstract_specs(proj_abs), mesh),
                )).lower(params_abs, tok_abs, pos_abs, state_abs, proj_abs)

    t_lower = time.monotonic() - t0
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    ca = ca if isinstance(ca, dict) else (ca[0] if ca else {})
    hlo = analyze_hlo(compiled.as_text())
    record = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "swan": swan_on, "status": "ok",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "xla_cost": {"flops": ca.get("flops", -1.0),
                     "bytes_accessed": ca.get("bytes accessed", -1.0)},
        "hlo_cost": {"flops": hlo.flops, "hbm_bytes": hlo.hbm_bytes,
                     "collective_bytes": hlo.collective_bytes,
                     "collective_count": hlo.collective_count,
                     "per_collective": hlo.per_collective},
    }
    record["roofline"] = roofline_report(record, cfg, shape, swan)
    return record


def _n_proj_layers(cfg) -> int:
    if cfg.mamba is not None:
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


def _abstract_specs(proj_abs):
    if proj_abs is None:
        return None
    return {"p_qk": P()}


def iter_cells(multi_pod: bool, swan_variants: bool = True):
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            yield arch, shape_name, multi_pod, False
            if (swan_variants and shape.kind != "train"
                    and swan_applicable(cfg)
                    and shape_applicable(cfg, shape)[0]):
                yield arch, shape_name, multi_pod, True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--swan", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--swan-mode", default="topk", choices=["topk", "truncate"])
    ap.add_argument("--swan-quantize", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=val (int), e.g. grad_accum=4")
    ap.add_argument("--tag", default="", help="suffix for the output file")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = int(v) if v.lstrip("-").isdigit() else v

    os.makedirs(args.out, exist_ok=True)
    cells = (list(iter_cells(args.multi_pod)) if args.all
             else [(args.arch, args.shape, args.multi_pod, args.swan)])
    n_fail = 0
    for arch, shape_name, mp, swan_on in cells:
        tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}{'__swan' if swan_on else ''}"
        if args.tag:
            tag += f"__{args.tag}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip existing] {tag}", flush=True)
            continue
        print(f"[cell] {tag} ...", flush=True)
        try:
            rec = build_cell(arch, shape_name, mp, swan_on,
                             swan_mode=args.swan_mode,
                             swan_quantize=args.swan_quantize,
                             overrides=overrides or None)
        except Exception as e:   # a failing cell is a bug — record & continue
            rec = {"arch": arch, "shape": shape_name, "multi_pod": mp,
                   "swan": swan_on, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            n_fail += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s"
                     f" coll={r['collective_s']:.2e}s dom={r['bottleneck']}"
                     f" (compile {rec['compile_s']}s)")
        print(f"[done] {tag}: {status}{extra}", flush=True)
    print(f"dry-run finished, failures: {n_fail}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
