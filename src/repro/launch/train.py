"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --batch 8 --seq 128 [--data tokens.bin]

Full-config multi-pod launches use the same code path with the production
mesh (runs on real TPU slices; on this CPU container use --smoke).
"""
from __future__ import annotations

import argparse

from repro.configs import (OptimizerConfig, TrainConfig, get_config,
                           get_smoke_config)
from repro.data.pipeline import FileStream
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--data", help="binary token file (default: synthetic)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.grad_accum:
        cfg = cfg.replace(grad_accum=args.grad_accum)
    tc = TrainConfig(
        model=cfg, seq_len=args.seq, global_batch=args.batch,
        steps=args.steps,
        optimizer=OptimizerConfig(lr=args.lr, decay_steps=args.steps,
                                  state_dtype=cfg.opt_state_dtype),
        checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
        log_every=max(args.steps // 20, 1),
        grad_compression=args.grad_compression)
    stream = None
    if args.data:
        stream = FileStream(args.data, cfg.vocab_size, args.batch, args.seq)
    out = Trainer(tc, stream=stream).run()
    for row in out["log"]:
        print(f"step {row['step']:6d}  loss {row['loss']:.4f}  "
              f"gnorm {row['grad_norm']:.3f}  lr {row['lr']:.2e}")
    if out["stragglers"]:
        print(f"watchdog: {len(out['stragglers'])} straggler steps flagged")
    print(f"finished at step {out['step']}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
