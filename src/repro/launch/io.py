"""Model input specs: ShapeDtypeStruct stand-ins + concrete batch builders.

``input_specs(cfg, shape)`` returns the abstract inputs for a (arch × shape)
cell — weak-type-correct, shardable, zero allocation — used by the dry-run.
``make_batch`` materialises small concrete batches for smoke tests.

Modality frontends are STUBS per the assignment: [vlm] gets precomputed
patch embeddings, [audio] precomputed frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def train_input_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs


def decode_input_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    return {"token": jax.ShapeDtypeStruct((batch,), jnp.int32)}


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
               ) -> Dict[str, Any]:
    """Concrete deterministic batch for smoke tests / examples."""
    rng = np.random.default_rng(seed)
    out: Dict[str, Any] = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                              jnp.int32),
    }
    if cfg.family == "vlm":
        out["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_prefix_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.dtype))
    return out
