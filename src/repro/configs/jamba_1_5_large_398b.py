"""Jamba-1.5-Large (398B) — hybrid Mamba+attention MoE [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2,
Mamba:attention 1:7 interleave (one attention layer per 8), MoE every
second layer (Jamba's e=2 period).  SWAN applies to the 9 attention layers
(the only sequence-proportional state).
"""
from repro.configs.base import ModelConfig, MoEConfig, MambaConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=24576, vocab_size=65536,
        norm="rmsnorm", act="silu", pos="none",   # jamba uses no positional encoding
        moe=MoEConfig(n_routed=16, n_shared=0, top_k=2, d_expert=24576,
                      moe_every=2, moe_offset=1, shard_experts=True),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn_period=8, attn_offset=4,
        tp_style="heads", fsdp_data=True, seq_shard=True,
        opt_state_dtype="bfloat16", grad_accum=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", act="silu", pos="none",
        moe=MoEConfig(n_routed=4, n_shared=0, top_k=2, d_expert=128,
                      moe_every=2, moe_offset=1, shard_experts=True),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        attn_period=8, attn_offset=4,
    )
