"""Llama-3-8B — dense GQA decoder [arXiv:2407.21783].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, RoPE theta 5e5.
This is the paper's own primary evaluation model family (Llama-3.1-8B).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=14336, vocab_size=128256,
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256,
        norm="rmsnorm", act="silu", rope_theta=500000.0,
    )
