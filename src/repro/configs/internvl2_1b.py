"""InternVL2-1B — VLM with Qwen2-0.5B text backbone [arXiv:2404.16821].

Backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655, QKV bias.
The InternViT frontend is a STUB per the assignment: ``input_specs()``
supplies 256 precomputed patch embeddings prepended to the token sequence.

14 heads / d_model 896 are not 16-divisible -> tp_style="fsdp_model": the
'model' mesh axis stores parameter shards (ZeRO-3 style) and activations
stay batch-sharded.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
        d_ff=4864, vocab_size=151655,
        norm="rmsnorm", act="silu", rope_theta=1000000.0,
        qkv_bias=True, n_prefix_tokens=256,
        tp_style="fsdp_model",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, d_head=8,
        d_ff=112, vocab_size=256,
        norm="rmsnorm", act="silu", qkv_bias=True, n_prefix_tokens=8,
        tp_style="fsdp_model",
    )
