"""Llama-3-405B — dense GQA decoder [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
Needs FSDP over data + TP over model + sequence sharding + grad accumulation
+ bf16 optimizer state to fit a 256-chip v5e pod.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
        d_ff=53248, vocab_size=128256,
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        tp_style="heads", fsdp_data=True, seq_shard=True,
        opt_state_dtype="bfloat16", grad_accum=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=192, vocab_size=256,
        norm="rmsnorm", act="silu", rope_theta=500000.0,
        fsdp_data=True, seq_shard=True, opt_state_dtype="bfloat16",
    )
