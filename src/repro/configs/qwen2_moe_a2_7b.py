"""Qwen1.5/2-MoE-A2.7B — MoE decoder [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (MHA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4. Qwen uses QKV biases.

60 experts are not divisible by the 16-way model axis, so EP is disabled for
this arch; experts stay replicated along 'model' and the expert *hidden* dim
(1408, divisible by 16) is tensor-parallel instead (TP-inside-expert).
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab_size=151936,
        norm="rmsnorm", act="silu", rope_theta=1000000.0,
        qkv_bias=True,
        moe=MoEConfig(n_routed=60, n_shared=4, top_k=4, d_expert=1408,
                      shard_experts=False),
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=96, vocab_size=256,
        norm="rmsnorm", act="silu", qkv_bias=True,
        moe=MoEConfig(n_routed=6, n_shared=2, top_k=2, d_expert=96,
                      shard_experts=False),
    )
