"""Whisper-small — encoder-decoder audio transformer [arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865, learned positions, pre-LayerNorm, GELU MLP.
The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies 1500 precomputed frame embeddings.

SWAN applies to the decoder self-attention cache; the static cross-attention
cache can additionally be winnowed once at encode time
(``SwanConfig.compress_cross_attn``, beyond-paper extension).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=3072, vocab_size=51865,
        norm="layernorm", act="gelu", pos="learned",
        qkv_bias=True, attn_out_bias=True, mlp_bias=True,
        is_encoder_decoder=True, n_encoder_layers=12, encoder_seq=1500,
        tp_style="fsdp_model",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256,
        norm="layernorm", act="gelu", pos="learned",
        qkv_bias=True, attn_out_bias=True, mlp_bias=True,
        is_encoder_decoder=True, n_encoder_layers=2, encoder_seq=32,
        tp_style="fsdp_model",
    )
