"""RWKV-6 (Finch) 3B — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

32L d_model=2560 (40 heads x 64) d_ff=8960 vocab=65536.

SWAN is INAPPLICABLE here: there is no KV cache to compress — serving state
is a constant-size [H, d_k, d_v] matrix per layer.  See DESIGN.md
§Arch-applicability.  long_500k runs natively (O(1) state).
"""
from repro.configs.base import ModelConfig, RWKVConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_head=64,
        d_ff=8960, vocab_size=65536,
        norm="layernorm", act="relu_sq",   # rwkv channel-mix uses squared relu
        pos="none",
        rwkv=RWKVConfig(head_dim=64),
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256,
        norm="layernorm", act="relu_sq", pos="none",
        rwkv=RWKVConfig(head_dim=16),
    )
