"""OLMo-1B — dense decoder with non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=8192, vocab_size=50304,
        norm="nonparam_ln", act="silu", rope_theta=10000.0,
        tie_embeddings=True,
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256,
        norm="nonparam_ln", act="silu", tie_embeddings=True,
    )
