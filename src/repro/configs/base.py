"""Configuration dataclasses for models, SWAN, shapes, training and serving.

Everything in the framework is driven by these frozen dataclasses.  Each
assigned architecture contributes one module in ``repro.configs`` exposing
``config()`` (the full published configuration) and ``smoke_config()`` (a
reduced same-family configuration used by CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (routed + shared experts)."""
    n_routed: int                 # number of routed experts
    n_shared: int                 # number of always-on shared experts
    top_k: int                    # experts activated per token
    d_expert: int                 # hidden dim of each expert FFN
    capacity_factor: float = 1.25  # token capacity per expert = cf * tokens * top_k / E
    router_aux_weight: float = 0.01  # load-balance auxiliary loss weight
    router_z_weight: float = 1e-3    # router logit z-loss weight
    moe_every: int = 1            # MoE FFN on layers where (idx % moe_every == moe_offset)
    moe_offset: int = 0
    shard_experts: bool = True    # EP: shard expert dim over the 'model' axis


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block configuration (used by Jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 (Finch) configuration."""
    head_dim: int = 64


@dataclass(frozen=True)
class SwanConfig:
    """SWAN KV-cache compression configuration.

    ``k_max`` is the allocation-time number of retained dimensions (real HBM
    footprint).  ``k_key``/``k_value`` are the *runtime* active dimensions
    (<= k_max); packed tails beyond them are zeroed, so they can be tuned per
    request without recompilation (paper's runtime tunability, restated for
    XLA static shapes).
    """
    enabled: bool = True
    k_max: int = 64               # allocated retained dims per vector
    buffer: int = 128             # dense ring-buffer length b (recent tokens)
    mode: str = "topk"            # "topk" (paper-faithful) | "truncate" (TPU-native dense low-rank)
    quantize: bool = False        # 8-bit values (paper's 8-bit variant)
    quant_dtype: str = "int8"     # "int8" (+ per-vector scale, robust) |
                                  # "fp8" (float8_e4m3fn direct cast — the
                                  # paper's literal '8-bit float', Eq.1 2k+2)
    k_key: Optional[int] = None   # runtime active dims for keys   (None -> k_max)
    k_value: Optional[int] = None  # runtime active dims for values (None -> k_max)
    compress_cross_attn: bool = False  # whisper extension: winnow static cross-attn cache

    @property
    def kk(self) -> int:
        return self.k_max if self.k_key is None else self.k_key

    @property
    def kv(self) -> int:
        return self.k_max if self.k_value is None else self.k_value

    def validate(self, d_head: int) -> None:
        if self.k_max > d_head:
            raise ValueError(f"k_max={self.k_max} > d_head={d_head}")
        if self.kk > self.k_max or self.kv > self.k_max:
            raise ValueError("runtime k exceeds allocated k_max")
        if self.mode not in ("topk", "truncate"):
            raise ValueError(f"unknown winnow mode {self.mode!r}")
        if self.quant_dtype not in ("int8", "fp8"):
            raise ValueError(f"unknown quant dtype {self.quant_dtype!r}")


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # --- normalisation / activations ----------------------------------
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"             # silu -> SwiGLU MLP; gelu -> GELU MLP
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    # --- positional ----------------------------------------------------
    pos: str = "rope"             # rope | learned | none
    rope_theta: float = 10000.0
    max_position: int = 1 << 20
    tie_embeddings: bool = False
    # --- family-specific ------------------------------------------------
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_period: int = 1          # hybrid: attention on layers where idx % attn_period == attn_offset
    attn_offset: int = 0
    # --- encoder-decoder -------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0          # fixed encoder frame count (whisper stub: 1500)
    # --- vlm --------------------------------------------------------------
    n_prefix_tokens: int = 0      # patch-embedding prefix length (internvl stub)
    # --- runtime / compilation -------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"    # full (save nothing) | dots (save matmul operands)
    scan_layers: bool = True
    # --- sharding profile -------------------------------------------------
    tp_style: str = "heads"       # heads | fsdp_model (tiny archs: model axis used for param storage)
    fsdp_data: bool = False       # additionally shard params/opt over 'data' (405B-class)
    seq_shard: bool = False       # sequence-parallel activations on 'model' axis
    opt_state_dtype: str = "float32"  # bf16 for >=100B configs (state compression)
    grad_accum: int = 1           # microbatch accumulation steps for train_4k

    # --- derived ----------------------------------------------------------
    @property
    def q_group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.rwkv is not None

    def layer_kind(self, idx: int) -> str:
        """Return 'attn' or 'mamba' for mixer at layer ``idx``."""
        if self.rwkv is not None:
            return "rwkv"
        if self.mamba is None:
            return "attn"
        return "attn" if idx % self.attn_period == self.attn_offset else "mamba"

    def ffn_kind(self, idx: int) -> str:
        if self.moe is None:
            return "dense"
        return "moe" if idx % self.moe.moe_every == self.moe.moe_offset else "dense"

    def n_params(self) -> int:
        """Analytical parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n = V * d                       # token embedding
        if not self.tie_embeddings:
            n += V * d                  # output head
        if self.pos == "learned":
            n += self.max_position_learned() * d
        enc_layers = self.n_encoder_layers if self.is_encoder_decoder else 0
        for idx in range(self.n_layers + enc_layers):
            is_enc = idx >= self.n_layers
            li = idx if not is_enc else idx - self.n_layers
            kind = "attn" if is_enc else self.layer_kind(li)
            if kind == "attn":
                n += self._attn_params()
                if self.is_encoder_decoder and not is_enc:
                    n += self._attn_params()   # cross attention
            elif kind == "mamba":
                n += self._mamba_params()
            elif kind == "rwkv":
                n += self._rwkv_params()
            fk = "dense" if is_enc else self.ffn_kind(li)
            if fk == "dense":
                n += self._mlp_params(ff)
            else:
                m = self.moe
                n += m.n_routed * self._mlp_params(m.d_expert)
                n += m.n_shared * self._mlp_params(m.d_expert)
                n += d * m.n_routed     # router
        return n

    def n_active_params(self) -> int:
        """Active parameters per token (MoE top-k counting)."""
        if self.moe is None:
            return self.n_params()
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n = V * d + (0 if self.tie_embeddings else V * d)
        for idx in range(self.n_layers):
            kind = self.layer_kind(idx)
            if kind == "attn":
                n += self._attn_params()
            elif kind == "mamba":
                n += self._mamba_params()
            if self.ffn_kind(idx) == "dense":
                n += self._mlp_params(ff)
            else:
                m = self.moe
                n += (m.top_k + m.n_shared) * self._mlp_params(m.d_expert)
                n += d * m.n_routed
        return n

    def max_position_learned(self) -> int:
        return min(self.max_position, 1 << 16)

    def _attn_params(self) -> int:
        d, H, Kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        n = d * H * dh + 2 * d * Kv * dh + H * dh * d
        if self.qkv_bias:
            n += H * dh + 2 * Kv * dh
        return n

    def _mlp_params(self, ff: int) -> int:
        mult = 3 if self.act == "silu" else 2   # swiglu has gate+up+down
        return mult * self.d_model * ff

    def _mamba_params(self) -> int:
        m = self.mamba
        d_in = m.expand * self.d_model
        dt_rank = m.dt_rank or -(-self.d_model // 16)
        n = self.d_model * 2 * d_in                 # in_proj (x & z)
        n += d_in * m.d_conv                        # causal conv
        n += d_in * (dt_rank + 2 * m.d_state)       # x -> dt, B, C
        n += dt_rank * d_in                         # dt_proj
        n += d_in * m.d_state + d_in                # A_log, D
        n += d_in * self.d_model                    # out_proj
        return n

    def _rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + decay/first + token-shift mixers (lora-ish small)
        return 5 * d * d + 4 * d + 2 * (d * 64)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for LM-family archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    "train",   4_096,   256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768,  32),
    "decode_32k":  ShapeConfig("decode_32k",  "decode",  32_768,  128),
    "long_500k":   ShapeConfig("long_500k",   "decode",  524_288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k":
        sub_quadratic = model.rwkv is not None or model.mamba is not None
        if not sub_quadratic:
            return False, ("pure full-attention arch: long_500k needs sub-quadratic "
                           "attention (see DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Train / serve configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    seq_len: int = 4096
    global_batch: int = 256
    steps: int = 1000
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    grad_compression: str = "none"   # none | int8
    loss_dtype: str = "float32"


@dataclass(frozen=True)
class ServeConfig:
    model: ModelConfig
    swan: SwanConfig = field(default_factory=SwanConfig)
    max_seq: int = 32_768
    batch: int = 128
    prefill_chunk: int = 2048
    seed: int = 0
