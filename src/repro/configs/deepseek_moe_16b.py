"""DeepSeekMoE-16B — fine-grained MoE decoder [arXiv:2401.06066].

28L d_model=2048 16H (MHA kv=16) d_ff=1408 vocab=102400,
MoE: 2 shared + 64 routed experts, top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab_size=102400,
        norm="rmsnorm", act="silu", rope_theta=10000.0,
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                      shard_experts=True),
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=96, vocab_size=256,
        norm="rmsnorm", act="silu",
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=96,
                      shard_experts=True),
    )
