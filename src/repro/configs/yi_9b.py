"""Yi-9B — llama-architecture dense GQA decoder [arXiv:2403.04652].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
kv=4 gives the strongest GQA grouping (G=8) in the pool — exercises SWAN's
grouped joint-SVD path hardest.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
        d_ff=11008, vocab_size=64000,
        norm="rmsnorm", act="silu", rope_theta=10000.0,
        tp_style="heads",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=1, d_head=8,
        d_ff=160, vocab_size=256,
        norm="rmsnorm", act="silu",
    )
