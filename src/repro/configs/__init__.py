"""Architecture registry.

``get_config(arch_id)`` / ``get_smoke_config(arch_id)`` resolve the ten
assigned architectures (plus the paper's own evaluation family, which is
llama3-8b).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    MambaConfig, ModelConfig, MoEConfig, OptimizerConfig, RWKVConfig,
    ServeConfig, ShapeConfig, SwanConfig, TrainConfig, SHAPES,
    shape_applicable,
)

_ARCH_MODULES: Dict[str, str] = {
    "deepseek-moe-16b":     "repro.configs.deepseek_moe_16b",
    "qwen2-moe-a2.7b":      "repro.configs.qwen2_moe_a2_7b",
    "llama3-8b":            "repro.configs.llama3_8b",
    "olmo-1b":              "repro.configs.olmo_1b",
    "llama3-405b":          "repro.configs.llama3_405b",
    "yi-9b":                "repro.configs.yi_9b",
    "internvl2-1b":         "repro.configs.internvl2_1b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "whisper-small":        "repro.configs.whisper_small",
    "rwkv6-3b":             "repro.configs.rwkv6_3b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch])


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()
