"""Beyond-paper extension: adaptive per-layer retention allocation.

The paper uses one global k_active.  But the calibration SVD already
exposes how fast each layer's spectrum decays: layers whose energy
concentrates in few dims tolerate aggressive pruning, flat-spectrum layers
do not.  ``allocate_k`` water-fills a global budget (avg_k · L) across
layers by keeping the globally-largest eigenvalues — per-layer k falls out
of the counts.

Deployment uses the runtime-tunability mechanism (per-layer k_active ≤
k_max zero-masks the packed tail), so adaptive allocation needs NO shape
changes and can be toggled per request — it composes with everything else.
Benchmarked against uniform allocation in bench_adaptive_k.py.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spectra_from_joint(s_eigvals: jnp.ndarray) -> np.ndarray:
    """[L, Kv, dh] descending eigenvalues -> per-layer spectrum [L, dh]
    (mean over KV heads, normalised per layer)."""
    e = np.asarray(s_eigvals, np.float64).mean(axis=1)
    e = np.maximum(e, 0.0)
    return e / np.maximum(e.sum(axis=1, keepdims=True), 1e-30)


def allocate_k(spectrum: np.ndarray, avg_k: int, k_min: int = 1,
               k_max: int | None = None) -> np.ndarray:
    """Water-fill a global budget of avg_k·L retained dims across layers.

    spectrum: [L, dh] per-layer normalised eigenvalues (descending).
    Returns k per layer [L] (ints in [k_min, k_max], sum == avg_k·L when
    feasible)."""
    L, dh = spectrum.shape
    k_max = k_max or dh
    budget = avg_k * L
    k = np.full(L, k_min, np.int64)
    budget -= k.sum()
    if budget < 0:
        raise ValueError("budget below k_min per layer")
    # marginal value of the next dim for each layer = its next eigenvalue
    flat = []
    for li in range(L):
        for j in range(k_min, k_max):
            flat.append((spectrum[li, j], li))
    flat.sort(reverse=True)
    for val, li in flat:
        if budget == 0:
            break
        if k[li] < k_max:
            k[li] += 1
            budget -= 1
    return k.astype(np.int32)


def uniform_k(n_layers: int, k: int) -> np.ndarray:
    return np.full(n_layers, k, np.int32)
