"""Paged storage for the SWAN sparse cache: memory follows live tokens.

The slab layout (``repro.core.hybrid_cache``) reserves ``[B, Kv, max_seq,
k_max]`` sparse rows per slot — worst-case memory, even for a slot decoding
its tenth token.  Here the per-layer sparse arrays become one shared pool of
fixed-size pages,

  pool side (per layer; model stacks L in front, like every cache leaf):
    vals  [n_pages, Kv, page_size, k_max]   (cfg dtype / int8 / fp8)
    idx   [n_pages, Kv, page_size, k_max]   int8   (topk mode)
    scale [n_pages, Kv, page_size]          f32    (int8 quant)

addressed through an int32 page table ``[n_slots, max_seq // page_size]``:
sparse token position ``t`` of slot ``s`` lives at physical page
``table[s, t // page_size]``, row ``t % page_size``.  Physical page 0 is
the trash page (never allocated): unmapped table entries point there, so
clamped garbage writes and gathers of not-yet-live pages are harmless (see
``repro.runtime.page_pool``).  One physical page id backs the same logical
page in EVERY layer and on BOTH k/v sides — one host allocation covers the
whole model.  Under the mesh-sharded serve engine the page axis is
partitioned over the mesh's ``data`` axis into equal per-shard blocks and
table entries are SHARD-LOCAL physical indices, so ``TRASH_PAGE`` (local
page 0) names each shard's own trash page — redirected garbage writes
never cross shards, and every function in this module runs unchanged on a
shard's local block inside ``shard_map``.

Paper Eq. 1 memory accounting, page-granular: each sparse vector still
costs k·(2+1) bytes (16-bit vals + int8 idx), or k·(1+1) (+4-byte scale)
quantized — paging changes WHEN that memory is committed, not how much a
token costs.  A physical page holds ``page_size`` token positions for both
sides of all L layers, so

  bytes/page = 2 · L_attn · Kv · page_size · per_vec(k_max)      (Eq. 1 rows)

and live cache bytes = live_pages · bytes/page + the dense ring buffers
(``2 · L · B · Kv · b · d_h`` — recent-token window, same as the slab
layout) — i.e. total memory tracks winnowed-token count, not
``n_slots · max_seq``.  Decompression-free reads are preserved: attention
gathers page granules by table lookup (``repro.core.swan_attention.
paged_logical_view``) and consumes the packed (values, indices) payload
directly — vectors are never expanded to d_h.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.hybrid_cache import (_val_dtype, chunk_evict_winnow,
                                     decode_evict_winnow,
                                     packed_vector_bytes, per_seq_pos)

Params = Dict[str, Any]

TRASH_PAGE = 0          # physical page 0, never allocated (repro.runtime.page_pool)


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------

def init_paged_pool(cfg, swan, n_pages: int, page_size: int) -> Params:
    """Allocate one layer's page pool (both sides)."""
    Kv, k = cfg.n_kv_heads, swan.k_max
    vdt = _val_dtype(cfg, swan)

    def side() -> Params:
        d: Params = {"vals": jnp.zeros((n_pages, Kv, page_size, k), vdt)}
        if swan.mode == "topk":
            d["idx"] = jnp.zeros((n_pages, Kv, page_size, k), jnp.int8)
        if swan.quantize and swan.quant_dtype == "int8":
            d["scale"] = jnp.zeros((n_pages, Kv, page_size), jnp.float32)
        return d

    return {"k": side(), "v": side()}


def page_bytes(cfg, swan, page_size: int) -> int:
    """Bytes committed by mapping ONE physical page (all layers, both
    sides) — ``page_size`` rows of the Eq. 1 packed payload
    (``hybrid_cache.packed_vector_bytes``: the single source of truth
    shared with the slab accounting)."""
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    return (2 * n_attn * cfg.n_kv_heads * page_size
            * packed_vector_bytes(cfg, swan))


def ring_bytes(cfg, swan, batch: int) -> int:
    """Dense ring buffers + positions (per-slot, not paged — the recent
    window is always live)."""
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i) == "attn")
    buf = 2 * n_attn * batch * cfg.n_kv_heads * swan.buffer * cfg.d_head \
        * jnp.dtype(cfg.dtype).itemsize
    return buf + n_attn * batch * swan.buffer * 4        # buf_pos int32


# ---------------------------------------------------------------------------
# Writes
# ---------------------------------------------------------------------------

def _pool_write_at(side: Params, packed: Params, phys: jnp.ndarray,
                   row: jnp.ndarray) -> Params:
    """Write packed single vectors [B, Kv, 1, ...] at per-sequence physical
    (page, row) addresses.  Distinct live sequences own distinct pages, so
    the only possible index collision is on the trash page."""
    out = dict(side)
    out["vals"] = side["vals"].at[phys, :, row].set(
        packed["vals"][:, :, 0].astype(side["vals"].dtype))
    if "idx" in side:
        out["idx"] = side["idx"].at[phys, :, row].set(packed["idx"][:, :, 0])
    if "scale" in side:
        out["scale"] = side["scale"].at[phys, :, row].set(
            packed["scale"][:, :, 0])
    return out


def paged_insert_decode(cache: Params, swan, cfg, k_hat: jnp.ndarray,
                        v_hat: jnp.ndarray, pos, page_tab: jnp.ndarray,
                        k_act=None) -> Params:
    """One decode step against the paged cache — the page-table analogue of
    ``hybrid_cache.swan_cache_insert_decode``, sharing its eviction/ring
    mechanics (``decode_evict_winnow``); only the sparse write is
    indirected THROUGH the page table: sparse position ``t`` ->
    (page_tab[b, t // ps], t % ps).  While a sequence has no sparse tokens
    its table row is all-trash, so the clamped t=0 garbage write lands in
    page 0 where masks hide it.  Dead lanes (pos < 0: free slots and slots
    mid chunked-prefill) write to the trash page outright.
    """
    ps = cache["pool"]["k"]["vals"].shape[2]
    write_idx, packed_k, packed_v, ring = decode_evict_winnow(
        cache, swan, k_hat, v_hat, pos, k_act)
    write_idx = jnp.maximum(write_idx, 0)       # b=0 path passes raw pos
    phys = jnp.take_along_axis(page_tab, (write_idx // ps)[:, None], 1)[:, 0]
    phys = jnp.where(per_seq_pos(pos, phys.shape[0]) >= 0, phys, TRASH_PAGE)
    row = write_idx % ps
    out = dict(cache)
    out.update(ring)
    out["pool"] = {
        "k": _pool_write_at(cache["pool"]["k"], packed_k, phys, row),
        "v": _pool_write_at(cache["pool"]["v"], packed_v, phys, row),
    }
    return out


def _pool_write_rows(side: Params, packed: Params, phys: jnp.ndarray,
                     row: jnp.ndarray) -> Params:
    """Write packed vectors [P, Kv, S, ...] at physical (page, row)
    addresses ``phys``/``row`` [P, S] — the chunked-prefill bulk write,
    one lane per in-flight prefill.  Distinct in-range positions of live
    lanes map to distinct (page, row) pairs (live lanes own disjoint
    pages); the only collisions are on the trash page, where any winner is
    fine."""
    out = dict(side)
    out["vals"] = side["vals"].at[phys, :, row].set(
        packed["vals"].swapaxes(1, 2).astype(side["vals"].dtype))
    if "idx" in side:
        out["idx"] = side["idx"].at[phys, :, row].set(
            packed["idx"].swapaxes(1, 2))
    if "scale" in side:
        out["scale"] = side["scale"].at[phys, :, row].set(
            packed["scale"].swapaxes(1, 2))
    return out


def paged_insert_prefill_chunk(cache: Params, swan, cfg, k_hat: jnp.ndarray,
                               v_hat: jnp.ndarray, start, true_len,
                               page_rows: jnp.ndarray, k_act=None,
                               dead=None) -> Params:
    """Insert prefill chunks ([P, S, Kv, dh], lane ``p`` at positions
    [start_p, start_p + true_len_p)) through the page table — the paged
    commit of the batched concurrent prefill, sharing the slab path's
    eviction/ring mechanics (``chunk_evict_winnow``).

    ``page_rows [P, Pg]`` holds each lane's page-table row (a prefix of
    length Pg).  Lane ``p``'s sparse position ``t`` lands at
    (page_rows[p, t // ps], t % ps); positions past the shipped prefix,
    and positions on not-yet-mapped pages (row = trash), write to the
    trash page — they are overshoot that later chunks rewrite once their
    pages exist.  ``dead [P]`` lanes (padding of a partially filled
    prefill batch) write to the trash page outright: their clamped lane
    gather may alias a LIVE slot's page row, and a garbage write there
    must not land.
    """
    ps = cache["pool"]["k"]["vals"].shape[2]
    Pg = page_rows.shape[1]
    dest, packed_k, packed_v, ring = chunk_evict_winnow(
        cache, swan, k_hat, v_hat, start, true_len, k_act)
    S = packed_k["vals"].shape[2]
    tok = dest[:, None] + jnp.arange(S)[None]               # [P, S]
    logical = tok // ps
    phys = jnp.where(
        logical < Pg,
        jnp.take_along_axis(page_rows, jnp.minimum(logical, Pg - 1), axis=1),
        TRASH_PAGE)
    if dead is not None:
        phys = jnp.where(dead[:, None], TRASH_PAGE, phys)
    row = tok % ps
    out = dict(cache)
    out.update(ring)
    out["pool"] = {
        "k": _pool_write_rows(cache["pool"]["k"], packed_k, phys, row),
        "v": _pool_write_rows(cache["pool"]["v"], packed_v, phys, row),
    }
    return out


def _scatter_side(pool_side: Params, slot_side: Params,
                  phys_rows: jnp.ndarray, page_size: int) -> Params:
    """Scatter ONE slot's slab-layout sparse side [L, 1, Kv, S, ...] into the
    pool [L, n_pages, ...] at physical pages ``phys_rows`` [S // page_size].

    All logical pages are written unconditionally (fixed shapes -> one
    compiled executable per prompt-length bucket): unmapped logical pages
    target the trash page, which absorbs the junk.
    """
    out = dict(pool_side)

    def to_pages(x, extra):
        L, _, Kv, S = x.shape[:4]
        P = S // page_size
        return x[:, 0].reshape((L, Kv, P, page_size) + extra) \
                      .swapaxes(1, 2)                    # [L, P, Kv, ps, ...]

    out["vals"] = pool_side["vals"].at[:, phys_rows].set(
        to_pages(slot_side["vals"], slot_side["vals"].shape[4:])
        .astype(pool_side["vals"].dtype))
    if "idx" in pool_side:
        out["idx"] = pool_side["idx"].at[:, phys_rows].set(
            to_pages(slot_side["idx"], slot_side["idx"].shape[4:]))
    if "scale" in pool_side:
        out["scale"] = pool_side["scale"].at[:, phys_rows].set(
            to_pages(slot_side["scale"], ()))
    return out


def paged_insert_prefill(state: Params, one: Params, slot,
                         phys_rows: jnp.ndarray, page_size: int) -> Params:
    """Admit a batch=1 prefilled slab state into the paged batched state:
    ring leaves scatter into lane ``slot`` of the batch axis; sparse sides
    scatter page-wise into the pool at the slot's physical pages.

    Shard-safe by construction (the mesh-sharded engine calls this inside
    ``shard_map`` on every shard with a LOCAL ``slot`` index): the ring
    scatter uses ``mode="drop"``, so non-owner shards — whose ``slot`` is
    parked out of range — write nothing, and their ``phys_rows`` are
    redirected to the local trash page, which absorbs the replicated
    pool scatter."""
    out = dict(state)
    out["pool"] = {
        "k": _scatter_side(state["pool"]["k"], one["k"], phys_rows, page_size),
        "v": _scatter_side(state["pool"]["v"], one["v"], phys_rows, page_size),
    }
    for leaf in ("buf_k", "buf_v", "buf_pos"):
        out[leaf] = state[leaf].at[:, slot].set(
            one[leaf][:, 0].astype(state[leaf].dtype), mode="drop")
    return out
