"""SWAN analytical models: memory (Eq. 1), FLOPs & break-even point (Eq. 2).

These are used by tests (cross-checked against counted reference FLOPs), the
Fig. 2a benchmark, and the roofline analysis.
"""
from __future__ import annotations

from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Eq. 1 — memory per sparse vector
# ---------------------------------------------------------------------------

def sparse_vector_bytes(k_active: int, bits8: bool = False) -> float:
    """Paper Eq. 1: 3k+2 bytes (fp16 vals + int8 idx + offset), 2k+2 for 8-bit."""
    return (2 * k_active + 2) if bits8 else (3 * k_active + 2)


def dense_vector_bytes(d_head: int, itemsize: int = 2) -> int:
    return d_head * itemsize


def compression_ratio(k_active: int, d_head: int, bits8: bool = False) -> float:
    """Fraction of dense size used by the sparse representation (<1 = saving)."""
    return sparse_vector_bytes(k_active, bits8) / dense_vector_bytes(d_head)


def memory_breakeven_retention(d_head: int, bits8: bool = False) -> float:
    """Retention ratio k/d_h at which sparse == dense (paper: ~0.66 @ fp16)."""
    per_dim = 2 if bits8 else 3
    return (2 * d_head - 2) / (per_dim * d_head)


# ---------------------------------------------------------------------------
# Eq. 2 / Appendix A.2 — FLOPs
# ---------------------------------------------------------------------------

def flops_standard(L: int, d_head: int) -> int:
    """C_std ≈ 4·L·d_h (Prop. A.3): score + output matvecs for one head."""
    return 4 * L * d_head


def flops_swan(L: int, d_head: int, k_active: int, b: int) -> int:
    """C_SWAN ≈ 4·d_h² + 4·(L−b)·k + 4·b·d_h (Prop. A.4)."""
    hist = max(L - b, 0)
    dense = min(L, b)
    return 4 * d_head * d_head + 4 * hist * k_active + 4 * dense * d_head


def breakeven_length(d_head: int, k_active: int, b: int) -> float:
    """Prop. A.5: SWAN is cheaper for L > d_h²/(d_h−k) + b."""
    if k_active >= d_head:
        return float("inf")
    return d_head * d_head / (d_head - k_active) + b


# ---------------------------------------------------------------------------
# Whole-model cache accounting
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CacheFootprint:
    dense_bytes: int
    swan_bytes: int

    @property
    def saving(self) -> float:
        return 1.0 - self.swan_bytes / self.dense_bytes


def model_cache_footprint(cfg, swan, batch: int, seq: int) -> CacheFootprint:
    """Per-token KV memory for the whole model, dense vs SWAN hybrid."""
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    itemsize = 2
    dense = 2 * n_attn * batch * cfg.n_kv_heads * seq * cfg.d_head * itemsize
    per_vec = sparse_vector_bytes(swan.k_max, swan.quantize)
    hist = max(seq - swan.buffer, 0)
    buf = min(seq, swan.buffer)
    swan_b = 2 * n_attn * batch * cfg.n_kv_heads * (
        hist * per_vec + buf * cfg.d_head * itemsize)
    return CacheFootprint(int(dense), int(swan_b))
