"""SWAN attention over the hybrid cache (paper Algorithm 1, lines 13-17).

The decode step attends to the *compressed* cache directly:

  scores = [ q̂ · expand(sparse) ‖ q̂ · buffer ] ;  o = softmax(scores) · V

The pure-JAX path computes scores as a gather over q̂ at the packed indices
and the value side as a scatter-add — no dense [S, dh] tensor is ever
materialised (the paper's sparse-dense matvec, TPU-translated per
DESIGN.md §2).  Under sequence sharding the sparse part runs as an
explicit split-S ``shard_map`` (flash-decoding): local gather/scatter per
shard plus one pmax/psum stat merge.  The Pallas kernel in
``repro.kernels.swan_decode`` performs the same computation with explicit
VMEM tiles and in-register expansion.

In ``truncate`` mode no gather/scatter happens at all: scores are a dense
low-rank dot over the leading k dims (pure MXU).

Batch-shardability (audited for the mesh-sharded serve engine): every
attention path here — decode, paged decode, and the bulk chunk-prefill
reads — is lane-local: gathers/scatters index each lane's own cache rows
(or its own page-table row), softmax stats reduce over sequence/k dims
only, and the ONLY collectives in this module are the opt-in split-S
pmax/psum merge above, which fires solely when sharding rules place the
sequence dim on a mesh axis.  The serve engine shards the BATCH axis via
``shard_map``, under which these functions run unchanged per shard.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.hybrid_cache import per_seq_pos, sparse_len
from repro.core.winnow import dequantize_int8, unpack_dense

Params = Dict[str, Any]


def _deq(side: Params) -> jnp.ndarray:
    """Packed values ready for matmul.  Non-quantized caches stay in their
    storage dtype (bf16): converting the whole cache to f32 would double the
    HBM bytes the decode step streams (§Perf iteration 1) — instead every
    contraction below accumulates in f32 via preferred_element_type."""
    vals = side["vals"]
    if "scale" in side:
        return dequantize_int8(vals, side["scale"], jnp.float32)
    if vals.dtype == jnp.float8_e4m3fn:   # paper's 8-bit float: direct cast
        return vals.astype(jnp.bfloat16)
    return vals


def _dot_f32(subscripts: str, a, b) -> jnp.ndarray:
    return jnp.einsum(subscripts, a, b, preferred_element_type=jnp.float32)


def _sparse_stats(qf: jnp.ndarray, k_side: Params, v_side: Params, swan,
                  sp_len, s_offset) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Flash-decoding partial stats over (a shard of) the sparse cache.

    Decompression-free (paper Algorithm 1 line 15, TPU-adapted):
      scores[t] = Σ_j k_vals[t,j] · q̂[k_idx[t,j]]          (gather over q̂)
      o[d]      = Σ_t Σ_j (p[t]·v_vals[t,j]) δ[v_idx[t,j]=d]  (scatter-add)
    No dense [S, dh] tensor is ever materialised.  In truncate mode the
    score collapses to a dense low-rank dot (pure MXU).

    ``sp_len`` is per-sequence [B]: each sequence masks its own valid
    sparse prefix (continuous batching decodes mixed-length sequences).

    Returns (m [B,Kv,G], l [B,Kv,G], o_unnorm [B,Kv,G,dh]) — mergeable
    partial softmax statistics.
    """
    B, Kv, G, dh = qf.shape
    S = k_side["vals"].shape[2]
    k_max = swan.k_max
    scale = 1.0 / math.sqrt(dh)
    trunc = "idx" not in k_side

    kv_ = _deq(k_side)                                 # [B,Kv,S,k]
    vv_ = _deq(v_side)
    if trunc:
        s_sp = _dot_f32("bjgk,bjtk->bjgt",
                        qf[..., :k_max].astype(kv_.dtype), kv_) * scale
    else:
        kidx = k_side["idx"].astype(jnp.int32)         # [B,Kv,S,k]
        # gather q̂ in the CACHE dtype: the [B,Kv,G,S,k] gather result is the
        # largest intermediate on the score side — keeping it bf16 halves
        # its traffic (f32 accumulation happens inside the dot)
        q_b = jnp.broadcast_to(qf.astype(kv_.dtype)[:, :, :, None, :],
                               (B, Kv, G, S, dh))
        q_at = jnp.take_along_axis(
            q_b, jnp.broadcast_to(kidx[:, :, None], (B, Kv, G, S, k_max)),
            axis=-1)
        s_sp = _dot_f32("bjgtk,bjtk->bjgt", q_at, kv_) * scale
    valid = ((s_offset + jnp.arange(S))[None, None, None, :]
             < sp_len[:, None, None, None])
    s_sp = jnp.where(valid, s_sp, -jnp.inf)

    m = s_sp.max(-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(s_sp - m_safe[..., None]), 0.0)
    l = p.sum(-1)

    if trunc:
        o = _dot_f32("bjgt,bjtk->bjgk", p.astype(vv_.dtype), vv_)
        o = jnp.pad(o, ((0, 0),) * 3 + ((0, dh - k_max),))
    else:
        vidx = v_side["idx"].astype(jnp.int32)
        w = p[..., None] * vv_[:, :, None]             # [B,Kv,G,S,k]
        o = jnp.zeros((B, Kv, G, dh), jnp.float32)
        bi, ji, gi = jnp.meshgrid(jnp.arange(B), jnp.arange(Kv),
                                  jnp.arange(G), indexing="ij")
        bi = jnp.broadcast_to(bi[..., None, None], w.shape)
        ji = jnp.broadcast_to(ji[..., None, None], w.shape)
        gi = jnp.broadcast_to(gi[..., None, None], w.shape)
        di = jnp.broadcast_to(vidx[:, :, None], w.shape)
        o = o.at[bi, ji, gi, di].add(w)
    return m_safe, l, o


def _sparse_stats_sharded(qf, cache, swan, sp_len, mesh, seq_axis: str):
    """Split-S across ``seq_axis``: each shard computes local stats over its
    sequence slice (everything local — gather/scatter stay single-device),
    then the O(dh) stats are merged with one pmax + two psums.  This is the
    flash-decoding schedule, written explicitly with shard_map so GSPMD
    cannot fall back to gathering the compressed cache."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.api import shard_map_compat

    B = qf.shape[0]
    S = cache["k"]["vals"].shape[2]
    n_shard = mesh.shape[seq_axis]
    s_local = S // n_shard

    # batch stays sharded over the remaining (data-parallel) axes
    dp = tuple(a for a in mesh.axis_names if a != seq_axis)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = dp if (dp and B % n_dp == 0 and B >= n_dp) else None

    side_spec = {"vals": P(bspec, None, seq_axis, None)}
    if "idx" in cache["k"]:
        side_spec["idx"] = P(bspec, None, seq_axis, None)
    if "scale" in cache["k"]:
        side_spec["scale"] = P(bspec, None, seq_axis)

    def local_fn(q, k_side, v_side, sp_len_):
        off = jax.lax.axis_index(seq_axis) * s_local
        m, l, o = _sparse_stats(q, k_side, v_side, swan, sp_len_, off)
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        o_g = jax.lax.psum(o * corr[..., None], seq_axis)
        return m_g, l_g, o_g

    return shard_map_compat(
        local_fn, mesh,
        (P(bspec, None, None, None), side_spec, side_spec, P(bspec)),
        (P(bspec, None, None), P(bspec, None, None),
         P(bspec, None, None, None)),
    )(qf, cache["k"], cache["v"], jnp.asarray(sp_len))


def swan_decode_attention(q_hat: jnp.ndarray, cache: Params, swan, cfg,
                          pos, mesh=None, seq_axis: Optional[str] = None
                          ) -> jnp.ndarray:
    """q̂ [B, Kv, G, dh] (rotated, grouped) -> o [B, Kv, G, dh] (rotated).

    Joint exact softmax over [winnowed sparse ‖ dense buffer].  ``pos`` may
    be a scalar (lockstep) or per-sequence [B] (continuous batching).  When
    ``mesh``/``seq_axis`` are given the sparse part runs as an explicit
    split-S shard_map (flash-decoding)."""
    B, Kv, G, dh = q_hat.shape
    S = cache["k"]["vals"].shape[2]
    qf = q_hat.astype(jnp.float32)
    pos = per_seq_pos(pos, B)
    sp_len = sparse_len(swan, pos)                     # [B]
    scale = 1.0 / math.sqrt(dh)

    if (mesh is not None and seq_axis in mesh.axis_names
            and S % mesh.shape[seq_axis] == 0 and S >= mesh.shape[seq_axis]):
        m_sp, l_sp, o_sp = _sparse_stats_sharded(qf, cache, swan, sp_len,
                                                 mesh, seq_axis)
    else:
        m_sp, l_sp, o_sp = _sparse_stats(qf, cache["k"], cache["v"], swan,
                                         sp_len, 0)

    if cache["buf_k"].shape[2] == 0:    # bt=0 ablation: sparse-only softmax
        denom = jnp.maximum(l_sp, 1e-30)
        return (o_sp / denom[..., None]).astype(q_hat.dtype)

    # ---- dense buffer part + exact merge ------------------------------------
    bk = cache["buf_k"]                                # [B,Kv,b,dh] storage dtype
    bv = cache["buf_v"]
    s_b = _dot_f32("bjgd,bjtd->bjgt", qf.astype(bk.dtype), bk) * scale
    b_valid = (cache["buf_pos"] >= 0) & (cache["buf_pos"] <= pos[:, None])
    s_b = jnp.where(b_valid[:, None, None], s_b, -jnp.inf)
    m_b = s_b.max(-1)
    m_b = jnp.where(jnp.isfinite(m_b), m_b, 0.0)
    p_b = jnp.where(b_valid[:, None, None], jnp.exp(s_b - m_b[..., None]), 0.0)
    l_b = p_b.sum(-1)
    o_b = _dot_f32("bjgt,bjtd->bjgd", p_b.astype(bv.dtype), bv)

    m = jnp.maximum(m_sp, m_b)
    c_sp = jnp.exp(m_sp - m)
    c_b = jnp.exp(m_b - m)
    denom = jnp.maximum(l_sp * c_sp + l_b * c_b, 1e-30)
    o = (o_sp * c_sp[..., None] + o_b * c_b[..., None]) / denom[..., None]
    return o.astype(q_hat.dtype)


def _sparse_stats_bulk(qf: jnp.ndarray, k_side: Params, v_side: Params,
                       swan, sp_len, dh: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Partial softmax stats of MANY queries ``qf [B, Kv, Q, dh]`` against
    (the valid prefix of) a packed sparse cache — the chunked-prefill bulk
    read.

    The decode-shaped gather/scatter in ``_sparse_stats`` touches
    O(Q · S · k) elements; with a chunk's Q = S_chunk · G queries that is
    the wrong kernel shape.  Here each packed vector is expanded ONCE
    (O(S · k) scatter, amortised over every query) into a chunk-local dense
    transient and both sides become plain MXU dots — the multi-query
    analogue.  The CACHE stays packed end to end and single-token decode
    never takes this path, so the decompression-free serving property is
    untouched; the [S, dh] view is the same transient scale a monolithic
    prefill's fresh k̂/v̂ occupy.
    """
    B, Kv, Q, _ = qf.shape
    S = k_side["vals"].shape[2]
    k_max = swan.k_max
    scale = 1.0 / math.sqrt(dh)
    kv_ = _deq(k_side)                                 # [B,Kv,S,k]
    vv_ = _deq(v_side)
    if "idx" in k_side:
        kd = unpack_dense(kv_, k_side["idx"], dh)      # [B,Kv,S,dh]
        s_sp = _dot_f32("bjqd,bjtd->bjqt", qf.astype(kd.dtype), kd) * scale
    else:                                              # truncate: low-rank dot
        s_sp = _dot_f32("bjqk,bjtk->bjqt",
                        qf[..., :k_max].astype(kv_.dtype), kv_) * scale
    valid = jnp.arange(S)[None, None, None, :] < sp_len[:, None, None, None]
    s_sp = jnp.where(valid, s_sp, -jnp.inf)
    m = s_sp.max(-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(s_sp - m_safe[..., None]), 0.0)
    l = p.sum(-1)
    if "idx" in v_side:
        vd = unpack_dense(vv_, v_side["idx"], dh)
        o = _dot_f32("bjqt,bjtd->bjqd", p.astype(vd.dtype), vd)
    else:
        o = _dot_f32("bjqt,bjtk->bjqk", p.astype(vv_.dtype), vv_)
        o = jnp.pad(o, ((0, 0),) * 3 + ((0, dh - k_max),))
    return m_safe, l, o


def swan_chunk_prefill_attention(q_hat: jnp.ndarray, k_hat: jnp.ndarray,
                                 v_new: jnp.ndarray, cache: Params, swan,
                                 cfg, start, true_len,
                                 sparse_stats=None) -> jnp.ndarray:
    """Attention for a prefill CHUNK resuming from a populated hybrid cache.

    ``q_hat [B, S, Kv, G, dh]`` / ``k_hat [B, S, Kv, dh]`` / ``v_new
    [B, S, Kv, dh]`` are the chunks' fresh rotated projections; ``start``
    may be a scalar or per-lane [B] — the batched concurrent prefill packs
    several slots' chunks into one call, lane ``p`` at absolute positions
    [start_p, start_p + S) against a ``cache`` holding its tokens
    [0, start_p) (a slab layout, or a ``paged_logical_view`` of each lane's
    pages).  Joint exact softmax per query over

        [ winnowed sparse prefix [0, start-b) ‖ ring [start-b, start) ‖
          chunk (causal) ]

    — i.e. the chunk sees older tokens exactly as a decode step at the same
    position would, and recent tokens (ring + chunk) dense.  Ring entries
    are additionally masked to positions < start so a just-freed slot's
    dirty ring (from the previous occupant, positions that may exceed
    ``start``) never leaks into a new prompt's first chunks.  Chunk padding
    keys sit at positions >= start + true_len > every real query position,
    so the causal mask hides them; padded queries (and whole dead lanes)
    produce garbage rows the caller discards.

    ``sparse_stats``: optional precomputed (m_safe, l, o_unnorm) partial
    stats over the sparse prefix, each in the bulk [B, Kv, S·G(, dh)]
    query-flattened layout — the Pallas bulk-chunk kernel
    (``repro.kernels.flash_prefill.swan_chunk``) supplies these and
    ``cache["k"]/["v"]`` are then never touched (the paged caller skips
    materialising the logical view entirely).
    """
    B, S, Kv, G, dh = q_hat.shape
    scale = 1.0 / math.sqrt(dh)
    start = per_seq_pos(start, B)                            # [B]
    qf = q_hat.astype(jnp.float32).transpose(0, 2, 1, 3, 4)  # [B,Kv,S,G,dh]

    if sparse_stats is not None:
        m_sp, l_sp, o_sp = sparse_stats
    else:
        sp_len = jnp.maximum(start - swan.buffer, 0)         # [B]
        m_sp, l_sp, o_sp = _sparse_stats_bulk(qf.reshape(B, Kv, S * G, dh),
                                              cache["k"], cache["v"], swan,
                                              sp_len, dh)
    m_sp = m_sp.reshape(B, Kv, S, G)
    l_sp = l_sp.reshape(B, Kv, S, G)
    o_sp = o_sp.reshape(B, Kv, S, G, dh)

    # ---- dense side: [old ring ‖ chunk] -------------------------------------
    kt = k_hat.transpose(0, 2, 1, 3)                         # [B,Kv,S,dh]
    vt = v_new.transpose(0, 2, 1, 3)
    bk = jnp.concatenate([cache["buf_k"], kt.astype(cache["buf_k"].dtype)],
                         axis=2)                             # [B,Kv,b+S,dh]
    bv = jnp.concatenate([cache["buf_v"], vt.astype(cache["buf_v"].dtype)],
                         axis=2)
    qpos = start[:, None] + jnp.arange(S)[None]              # [B, S]
    kpos = jnp.concatenate([cache["buf_pos"], qpos], axis=1)
    in_seq = jnp.concatenate(                                # [B, b+S]
        [cache["buf_pos"] < start[:, None], jnp.ones((B, S), bool)], axis=1)
    valid = ((kpos[:, None, :] >= 0)
             & (kpos[:, None, :] <= qpos[:, :, None])
             & in_seq[:, None, :])                           # [B, S, b+S]
    s_b = _dot_f32("bjsgd,bjtd->bjsgt", qf.astype(bk.dtype), bk) * scale
    s_b = jnp.where(valid[:, None, :, None, :], s_b, -jnp.inf)
    m_b = s_b.max(-1)
    m_b = jnp.where(jnp.isfinite(m_b), m_b, 0.0)
    p_b = jnp.where(valid[:, None, :, None, :],
                    jnp.exp(s_b - m_b[..., None]), 0.0)
    l_b = p_b.sum(-1)
    o_b = _dot_f32("bjsgt,bjtd->bjsgd", p_b.astype(bv.dtype), bv)

    # ---- exact merge --------------------------------------------------------
    m = jnp.maximum(m_sp, m_b)
    c_sp = jnp.exp(m_sp - m)
    c_b = jnp.exp(m_b - m)
    denom = jnp.maximum(l_sp * c_sp + l_b * c_b, 1e-30)
    o = (o_sp * c_sp[..., None] + o_b * c_b[..., None]) / denom[..., None]
    return o.transpose(0, 2, 1, 3, 4).reshape(B, S, Kv * G, dh) \
            .astype(q_hat.dtype)


# ---------------------------------------------------------------------------
# Paged cache (repro.core.paged_cache): gather-via-page-table reads
# ---------------------------------------------------------------------------

def paged_logical_view(cache: Params, page_tab: jnp.ndarray) -> Params:
    """Assemble each sequence's logical sparse cache from the shared page
    pool by page-table gather: ``view[b, :, t] = pool[page_tab[b, t // ps],
    :, t % ps]``.  This is a page-granule gather of the PACKED payload —
    vectors stay (values, int8 indices) pairs end to end, so the
    decompression-free property is untouched; the gathered view feeds the
    exact same sparse gather/scatter attention as the slab layout.

    Unmapped logical pages gather the trash page (physical page 0); the
    per-sequence ``sp_len`` mask inside ``_sparse_stats`` hides them.

    ``page_tab`` may be a leading PREFIX of the full table (the engine
    ships a power-of-two bucket of >= the most pages any live sequence has
    mapped), so the gathered view — the step's transient memory — scales
    with live pages, not max_seq.
    """
    B, P = page_tab.shape

    def side_view(side: Params) -> Params:
        ps = side["vals"].shape[2]

        def g(x):
            v = x[page_tab]                        # [B, P, Kv, ps, ...]
            v = jnp.moveaxis(v, 2, 1)              # [B, Kv, P, ps, ...]
            return v.reshape((B, v.shape[1], P * ps) + v.shape[4:])

        return {name: g(x) for name, x in side.items()}

    return {"k": side_view(cache["pool"]["k"]),
            "v": side_view(cache["pool"]["v"]),
            "buf_k": cache["buf_k"], "buf_v": cache["buf_v"],
            "buf_pos": cache["buf_pos"]}


def swan_decode_attention_paged(q_hat: jnp.ndarray, cache: Params, swan, cfg,
                                pos, page_tab: jnp.ndarray, mesh=None,
                                seq_axis: Optional[str] = None) -> jnp.ndarray:
    """SWAN decode attention over the paged cache: page-table gather, then
    the identical joint softmax over [winnowed sparse ‖ dense buffer].
    Every position < sp_len lives in a mapped page of the shipped table
    prefix, and positions beyond the view were -inf-masked anyway — so the
    paged engine is token-identical to the slab engine."""
    return swan_decode_attention(q_hat, paged_logical_view(cache, page_tab),
                                 swan, cfg, pos, mesh=mesh, seq_axis=seq_axis)


# ---------------------------------------------------------------------------
# Reference (oracle) path: full decompression + dense softmax.  Used by tests
# and by the Pallas ref.py — NEVER by serving.
# ---------------------------------------------------------------------------

def swan_decode_attention_reference(q_hat: jnp.ndarray, cache: Params, swan,
                                    cfg, pos) -> jnp.ndarray:
    B, Kv, G, dh = q_hat.shape
    S = cache["k"]["vals"].shape[2]
    pos = per_seq_pos(pos, B)

    def side_dense(side):
        vals = side["vals"]
        if "scale" in side:
            vals = dequantize_int8(vals, side["scale"], jnp.float32)
        return unpack_dense(vals.astype(jnp.float32), side.get("idx"), dh)

    kd, vd = side_dense(cache["k"]), side_dense(cache["v"])
    qf = q_hat.astype(jnp.float32)
    s_sp = jnp.einsum("bjgd,bjtd->bjgt", qf, kd) / math.sqrt(dh)
    sp_valid = jnp.arange(S)[None, :] < sparse_len(swan, pos)[:, None]
    s_sp = jnp.where(sp_valid[:, None, None], s_sp, -jnp.inf)

    bk = cache["buf_k"].astype(jnp.float32)
    bv = cache["buf_v"].astype(jnp.float32)
    s_b = jnp.einsum("bjgd,bjtd->bjgt", qf, bk) / math.sqrt(dh)
    b_valid = (cache["buf_pos"] >= 0) & (cache["buf_pos"] <= pos[:, None])
    s_b = jnp.where(b_valid[:, None, None], s_b, -jnp.inf)

    s = jnp.concatenate([s_sp, s_b], axis=-1)
    w = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([vd, bv], axis=2)
    o = jnp.einsum("bjgt,bjtd->bjgd", w, v_all)
    return o.astype(q_hat.dtype)
