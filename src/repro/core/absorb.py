"""SWAN weight absorption (§4.2): fold P_VO into W_V / W_O offline.

After absorption:
  * value vectors are produced directly in the rotated space
    (Ŵ_V = W_V · P_VO per KV head),
  * the output projection undoes the rotation
    (Ŵ_O^(j) = P_VO,expandedᵀ · W_O^(j) per query head),
so the value-side rotation has ZERO runtime cost (paper Lemma A.2 proves the
combination is exactly lossless).

P_QK cannot be absorbed (RoPE does not commute with a static matrix) and is
applied at runtime by ``repro.core.winnow.rotate_q/rotate_k``.

All functions accept either a single layer's attention params or a stacked
[L, ...] tree (scan-over-layers layout) — the leading-axis handling is
automatic.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

Params = Dict[str, Any]


def _absorb_wv(wv: jnp.ndarray, p_vo: jnp.ndarray, n_kv: int, d_head: int) -> jnp.ndarray:
    """wv [d, Kv·dh] x p_vo [Kv, dh, dh] -> Ŵ_V [d, Kv·dh]."""
    d = wv.shape[0]
    w = wv.reshape(d, n_kv, d_head)
    w = jnp.einsum("dje,jef->djf", w.astype(jnp.float32),
                   p_vo.astype(jnp.float32))
    return w.reshape(d, n_kv * d_head).astype(wv.dtype)


def _absorb_bv(bv: jnp.ndarray, p_vo: jnp.ndarray, n_kv: int, d_head: int) -> jnp.ndarray:
    b = bv.reshape(n_kv, d_head)
    b = jnp.einsum("je,jef->jf", b.astype(jnp.float32), p_vo.astype(jnp.float32))
    return b.reshape(-1).astype(bv.dtype)


def _absorb_wo(wo: jnp.ndarray, p_vo: jnp.ndarray, n_heads: int, n_kv: int,
               d_head: int) -> jnp.ndarray:
    """wo [H·dh, d]: each head slice W_O^(j) [dh, d] gets P_VOᵀ premultiplied,
    with P_VO repeated for each query head in the KV group."""
    d = wo.shape[-1]
    G = n_heads // n_kv
    w = wo.reshape(n_kv, G, d_head, d)
    w = jnp.einsum("jef,jged->jgfd", p_vo.astype(jnp.float32),
                   w.astype(jnp.float32))   # (P_VOᵀ W_O)[f,d] = Σ_e P[e,f]·W[e,d]
    return w.reshape(n_heads * d_head, d).astype(wo.dtype)


def absorb_vo(attn_params: Params, p_vo: jnp.ndarray, n_heads: int,
              n_kv: int, d_head: int) -> Params:
    """Return attention params with Ŵ_V / Ŵ_O (and b̂_v).  Handles both a
    single layer ([d, ...] weights, p_vo [Kv, dh, dh]) and stacked layers
    ([L, d, ...] weights, p_vo [L, Kv, dh, dh])."""
    stacked = attn_params["wv"].ndim == 3
    out = dict(attn_params)
    if stacked:
        import jax
        out["wv"] = jax.vmap(lambda w, p: _absorb_wv(w, p, n_kv, d_head))(
            attn_params["wv"], p_vo)
        out["wo"] = jax.vmap(lambda w, p: _absorb_wo(w, p, n_heads, n_kv, d_head))(
            attn_params["wo"], p_vo)
        if "bv" in attn_params:
            out["bv"] = jax.vmap(lambda b, p: _absorb_bv(b, p, n_kv, d_head))(
                attn_params["bv"], p_vo)
    else:
        out["wv"] = _absorb_wv(attn_params["wv"], p_vo, n_kv, d_head)
        out["wo"] = _absorb_wo(attn_params["wo"], p_vo, n_heads, n_kv, d_head)
        if "bv" in attn_params:
            out["bv"] = _absorb_bv(attn_params["bv"], p_vo, n_kv, d_head)
    return out
