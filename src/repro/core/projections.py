"""SWAN offline calibration: joint-subspace SVD projection matrices (§4.1).

For every attention layer ``l`` and KV-head ``j`` we build two orthogonal
bases:

  P_QK[l,j] = right-singular basis of  S_QK = concat(Q_grouped, K)
  P_VO[l,j] = right-singular basis of  S_VO = concat(V, W_O_groupedᵀ)

where Q/K are collected *after* RoPE (their state just before the attention
score computation) and the W_O slices are grouped exactly like the query
heads (G = H/Kv heads per KV head).

The SVD is computed via the Gram matrix eigendecomposition
(``eigh(SᵀS)``, eigenvalues descending) which is equivalent for the
right-singular vectors and much cheaper than a full SVD of an
[n_tokens·(G+1), d_h] matrix.

Columns of P are ordered by decreasing singular value, so energy is
concentrated in the *leading* rotated dimensions — the property both the
paper's top-k winnowing and our TPU-native truncation mode exploit.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def gram_basis_eigs(s: jnp.ndarray):
    """(P [d,d], eigenvalues [d] descending) of the Gram matrix of s [N,d]."""
    s = s.astype(jnp.float32)
    gram = s.T @ s                                   # [d, d]
    gram = gram + 1e-6 * jnp.eye(s.shape[-1], dtype=jnp.float32)
    eigvals, eigvecs = jnp.linalg.eigh(gram)          # ascending
    return eigvecs[:, ::-1], eigvals[::-1]


def gram_basis(s: jnp.ndarray) -> jnp.ndarray:
    """Right-singular basis of s [N, d]; columns by descending σ."""
    return gram_basis_eigs(s)[0]


def _group_queries(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """q [B, S, H, dh] -> [Kv, B·S·G, dh] (paper §4.1.1 reshape)."""
    B, S, H, dh = q.shape
    G = H // n_kv
    q = q.reshape(B, S, n_kv, G, dh)
    return q.transpose(2, 0, 1, 3, 4).reshape(n_kv, B * S * G, dh)


def _group_wo(wo: jnp.ndarray, n_heads: int, n_kv: int, d_head: int) -> jnp.ndarray:
    """wo [H·dh, d] -> [Kv, G·d, dh]: per-KV-group stack of W_O^(j)ᵀ slices."""
    d = wo.shape[-1]
    G = n_heads // n_kv
    per_head = wo.reshape(n_heads, d_head, d)          # [H, dh, d]
    grouped = per_head.reshape(n_kv, G, d_head, d)
    return grouped.transpose(0, 1, 3, 2).reshape(n_kv, G * d, d_head)


def layer_projections(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      wo: jnp.ndarray, n_heads: int, n_kv: int,
                      d_head: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute (P_QK [Kv, dh, dh], P_VO [Kv, dh, dh]) for one layer.

    q: [B, S, H, dh] (post-RoPE), k/v: [B, S, Kv, dh], wo: [H·dh, d].
    """
    B, S = k.shape[:2]
    qg = _group_queries(q, n_kv)                       # [Kv, BSG, dh]
    kg = k.transpose(2, 0, 1, 3).reshape(n_kv, B * S, d_head)
    vg = v.transpose(2, 0, 1, 3).reshape(n_kv, B * S, d_head)
    wog = _group_wo(wo, n_heads, n_kv, d_head)         # [Kv, G·d, dh]

    s_qk = jnp.concatenate([qg, kg], axis=1)           # [Kv, BSG+BS, dh]
    s_vo = jnp.concatenate([vg, wog], axis=1)          # [Kv, BS+G·d, dh]
    p_qk, e_qk = jax.vmap(gram_basis_eigs)(s_qk)
    p_vo, e_vo = jax.vmap(gram_basis_eigs)(s_vo)
    return p_qk, p_vo, e_qk, e_vo


def compute_projections(qkv_per_layer: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
                        wo_per_layer: jnp.ndarray, n_heads: int, n_kv: int,
                        d_head: int) -> Params:
    """Vectorised over the (stacked) layer axis.

    qkv_per_layer: (q [L,B,S,H,dh], k [L,B,S,Kv,dh], v [L,B,S,Kv,dh]);
    wo_per_layer: [L, H·dh, d].
    Returns {"p_qk": [L,Kv,dh,dh], "p_vo": [L,Kv,dh,dh]} (float32).
    """
    q, k, v = qkv_per_layer
    fn = lambda q_, k_, v_, wo_: layer_projections(q_, k_, v_, wo_,
                                                   n_heads, n_kv, d_head)
    p_qk, p_vo, e_qk, e_vo = jax.vmap(fn)(q, k, v, wo_per_layer)
    # spectra [L,Kv,dh] enable the adaptive per-layer-k extension
    return {"p_qk": p_qk, "p_vo": p_vo,
            "spectrum_qk": e_qk, "spectrum_vo": e_vo}


def random_orthogonal(key, shape_prefix: Tuple[int, ...], d: int) -> jnp.ndarray:
    """Random orthogonal bases (paper Table 3 'Random Projection' ablation)."""
    n = 1
    for s in shape_prefix:
        n *= s
    keys = jax.random.split(key, n)

    def one(k):
        g = jax.random.normal(k, (d, d), jnp.float32)
        qmat, r = jnp.linalg.qr(g)
        return qmat * jnp.sign(jnp.diagonal(r))[None, :]

    out = jax.vmap(one)(keys)
    return out.reshape(*shape_prefix, d, d)


def check_orthogonal(p: jnp.ndarray, atol: float = 1e-3) -> jnp.ndarray:
    """Max |PᵀP − I| over all bases in a stacked array."""
    d = p.shape[-1]
    eye = jnp.eye(d, dtype=jnp.float32)
    prod = jnp.einsum("...ij,...ik->...jk", p.astype(jnp.float32),
                      p.astype(jnp.float32))
    return jnp.max(jnp.abs(prod - eye))
