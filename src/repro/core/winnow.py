"""SWAN winnowing: runtime rotation, magnitude pruning, packing, quantization.

Two winnow modes (DESIGN.md §2):
  * ``topk``     — paper-faithful: keep the k_max largest-|·| dims per vector,
                   store (values, int8 indices).  Packed fixed-width layout
                   (byte-identical to the paper's CSR payload, Eq. 1).
  * ``truncate`` — TPU-native beyond-paper mode: keep the *first* k_max dims
                   of the SVD-rotated vector (dense low-rank slice, no index
                   storage).

Runtime tunability: ``k_active <= k_max`` zeroes the packed tail, so the
effective retention can be changed per request without recompilation.

Quantization (paper §4.3 / Eq. 1 8-bit variant): symmetric int8 with a
per-vector float16 scale.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Runtime rotation (P_QK — cannot be absorbed because of RoPE, §4.2)
# ---------------------------------------------------------------------------

def rotate_q(q: jnp.ndarray, p_qk: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """q [B, S, H, dh] x p_qk [Kv, dh, dh] -> q̂ [B, S, Kv, G, dh]."""
    B, S, H, dh = q.shape
    G = H // n_kv
    qg = q.reshape(B, S, n_kv, G, dh)
    return jnp.einsum("bsjgd,jde->bsjge", qg, p_qk.astype(q.dtype))


def rotate_k(k: jnp.ndarray, p_qk: jnp.ndarray) -> jnp.ndarray:
    """k [B, S, Kv, dh] x p_qk [Kv, dh, dh] -> k̂ [B, S, Kv, dh]."""
    return jnp.einsum("bsjd,jde->bsje", k, p_qk.astype(k.dtype))


# ---------------------------------------------------------------------------
# Pruning / packing
# ---------------------------------------------------------------------------

def _live_mask(k_active: jnp.ndarray, k_max: int, out_ndim: int) -> jnp.ndarray:
    """Broadcastable ``col < k_active`` mask.  ``k_active`` may be a scalar
    (whole batch) or a leading-batch-shaped array ([B] for per-request k) —
    its axes align with the *leading* axes of the packed [..., k_max] tensor."""
    k_active = jnp.asarray(k_active)
    live = jnp.arange(k_max) < k_active[..., None]
    return live.reshape(k_active.shape
                        + (1,) * (out_ndim - 1 - k_active.ndim) + (k_max,))


def topk_pack(x: jnp.ndarray, k_max: int,
              k_active: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-vector magnitude top-k (paper Algorithm 1 lines 7-11).

    x: [..., dh] -> (vals [..., k_max] same dtype, idx [..., k_max] int8).
    If ``k_active`` (traced scalar or per-sequence [B], leading-axis-aligned)
    is given, packed columns >= k_active are zeroed — the runtime
    compression knob.

    Implemented as a stable co-sort (values and indices ride along the
    |x| keys) rather than top_k + take_along_axis: GSPMD replicates batch
    dims around the gather, all-gathering the full [B,Kv,S,dh] pre-winnow
    tensor per layer (§Perf cell D — 312 GB/device of collectives in the
    32k prefill before this change).  Stable sort keeps lax.top_k's
    lowest-index tie-breaking, so outputs are bit-identical.
    """
    dh = x.shape[-1]
    if k_max > dh:
        raise ValueError(f"k_max={k_max} > d_head={dh}")
    mag = jnp.abs(x.astype(jnp.float32))
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    _, vals, idx = jax.lax.sort((-mag, x, iota), dimension=-1, num_keys=1,
                                is_stable=True)
    vals, idx = vals[..., :k_max], idx[..., :k_max]
    if k_active is not None:
        vals = jnp.where(_live_mask(k_active, k_max, vals.ndim), vals, 0)
    return vals, idx.astype(jnp.int8)


def truncate_pack(x: jnp.ndarray, k_max: int,
                  k_active: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Keep leading k_max rotated dims (dense low-rank).  [..., dh] -> [..., k_max]."""
    vals = x[..., :k_max]
    if k_active is not None:
        vals = jnp.where(_live_mask(k_active, k_max, vals.ndim), vals, 0)
    return vals


def unpack_dense(vals: jnp.ndarray, idx: Optional[jnp.ndarray],
                 dh: int) -> jnp.ndarray:
    """Expand packed vectors to dense [..., k] -> [..., dh].  Used by the
    reference oracle and by the chunked-prefill BULK read
    (``swan_attention._sparse_stats_bulk``: expand once, amortised over a
    chunk's many queries, into a chunk-local transient).  The single-token
    decode path never calls this — the cache itself stays packed in HBM."""
    if idx is None:   # truncate mode
        pad = [(0, 0)] * (vals.ndim - 1) + [(0, dh - vals.shape[-1])]
        return jnp.pad(vals, pad)
    dense = jnp.zeros((*vals.shape[:-1], dh), vals.dtype)
    return jnp.put_along_axis(dense, idx.astype(jnp.int32), vals, axis=-1,
                              inplace=False)


# ---------------------------------------------------------------------------
# Quantization (int8 symmetric, per-vector scale)
# ---------------------------------------------------------------------------

def quantize_int8(vals: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[..., k] -> (int8 [..., k], scale f32 [...])."""
    absmax = jnp.max(jnp.abs(vals.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(vals.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Combined winnow step used by the hybrid cache
# ---------------------------------------------------------------------------

def winnow_vector(x: jnp.ndarray, swan, which: str,
                  k_act: Optional[jnp.ndarray] = None) -> Params:
    """Winnow rotated vectors x [..., dh] per the SwanConfig.

    which: 'k' or 'v' (separate runtime retention knobs, paper Table 2).
    ``k_act``: optional traced override of the runtime retention — used by
    the adaptive per-layer-k extension (repro.core.adaptive).
    Returns dict with 'vals' (+ 'idx' for topk, + 'scale' if quantized).
    """
    if k_act is None:
        k_active = swan.kk if which == "k" else swan.kv
        k_act = None if k_active == swan.k_max else jnp.asarray(k_active)
    if swan.mode == "topk":
        vals, idx = topk_pack(x, swan.k_max, k_act)
        out: Params = {"vals": vals, "idx": idx}
    else:
        out = {"vals": truncate_pack(x, swan.k_max, k_act)}
    if swan.quantize:
        if swan.quant_dtype == "fp8":
            # paper's literal "8-bit float": direct cast, no scale (Eq. 1:
            # 2k+2 bytes/vector); e4m3 range (±448) covers rotated K/V
            out["vals"] = out["vals"].astype(jnp.float8_e4m3fn)
        else:
            q, scale = quantize_int8(out["vals"])
            out["vals"] = q
            out["scale"] = scale
    return out
