"""SWAN hybrid KV cache (§4.3, Figure 1): dense ring buffer + packed sparse.

Layout per attention layer (model stacks a leading L axis when scanning):

  sparse (historical, winnowed) — indexed directly by token position:
    k_vals [B, Kv, S, k_max]   (cfg dtype, or int8 when quantized)
    k_idx  [B, Kv, S, k_max]   int8   (topk mode only)
    k_scale[B, Kv, S]          f32    (quantized only)         (same for v_*)
  buffer (recent, dense):
    buf_k / buf_v [B, Kv, b, dh]
    buf_pos [B, b] int32 — token position held in each ring slot (-1 = empty)

Ring semantics: token ``t`` lives in slot ``t % b``.  At decode step ``pos``
the slot's previous occupant (token ``pos - b``) is winnowed and written to
the sparse cache at its own position — Algorithm 1's pop-oldest, with XLA
fixed shapes.  While ``pos < b`` the evicted slot is empty (buf_pos = -1);
the clamped sparse write lands in the still-invalid region (< sp_len mask)
so no guard select over the big arrays is needed.

``pos`` may be a scalar (lockstep batch) or a per-sequence ``[B]`` vector —
the continuous-batching engine decodes sequences at independent positions,
so ring state and validity masks are tracked per sequence.

Memory accounting matches paper Eq. 1: the packed payload per vector is
k·(2+1) bytes (16-bit vals + int8 idx) or k·(1+1) (+scale) when quantized.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.winnow import winnow_vector

Params = Dict[str, Any]


def _val_dtype(cfg, swan):
    if swan.quantize:
        return jnp.float8_e4m3fn if swan.quant_dtype == "fp8" else jnp.int8
    return jnp.dtype(cfg.dtype)


def init_swan_cache(cfg, swan, batch: int, max_seq: int) -> Params:
    """Allocate one layer's hybrid cache."""
    Kv, dh, b, k = cfg.n_kv_heads, cfg.d_head, swan.buffer, swan.k_max
    vdt = _val_dtype(cfg, swan)
    side = lambda: _side(batch, Kv, max_seq, k, vdt, swan)
    return {
        "k": side(), "v": side(),
        "buf_k": jnp.zeros((batch, Kv, b, dh), jnp.dtype(cfg.dtype)),
        "buf_v": jnp.zeros((batch, Kv, b, dh), jnp.dtype(cfg.dtype)),
        "buf_pos": jnp.full((batch, b), -1, jnp.int32),
    }


def per_seq_pos(pos, batch: int) -> jnp.ndarray:
    """Normalise a scalar-or-[B] decode position to int32 [B]."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _side(B, Kv, S, k, vdt, swan) -> Params:
    d: Params = {"vals": jnp.zeros((B, Kv, S, k), vdt)}
    if swan.mode == "topk":
        d["idx"] = jnp.zeros((B, Kv, S, k), jnp.int8)
    if swan.quantize and swan.quant_dtype == "int8":
        d["scale"] = jnp.zeros((B, Kv, S), jnp.float32)
    return d


def packed_vector_bytes(cfg, swan) -> int:
    """Physical bytes of ONE packed sparse vector (the Eq. 1 payload in
    this config's actual dtypes).  Single source of truth for the slab
    accounting below and the paged-pool accounting in
    ``repro.core.paged_cache``."""
    k = swan.k_max
    per_vec = k * (1 if swan.quantize else jnp.dtype(cfg.dtype).itemsize)
    if swan.mode == "topk":
        per_vec += k                      # int8 indices
    if swan.quantize and swan.quant_dtype == "int8":
        per_vec += 4                      # f32 scale (fp8 needs none)
    return per_vec


def cache_bytes(cfg, swan, batch: int, max_seq: int) -> int:
    """Physical bytes of one layer's hybrid cache (cf. paper Eq. 1)."""
    Kv, dh, b = cfg.n_kv_heads, cfg.d_head, swan.buffer
    sparse = 2 * batch * Kv * max_seq * packed_vector_bytes(cfg, swan)
    buffer = 2 * batch * Kv * b * dh * jnp.dtype(cfg.dtype).itemsize
    return sparse + buffer


def dense_cache_bytes(cfg, batch: int, max_seq: int) -> int:
    Kv, dh = cfg.n_kv_heads, cfg.d_head
    return 2 * batch * Kv * max_seq * dh * jnp.dtype(cfg.dtype).itemsize


# ---------------------------------------------------------------------------
# Updates
# ---------------------------------------------------------------------------

def _write_sparse(side: Params, packed: Params, idx3) -> Params:
    """Write packed vectors [B, Kv, n, ...] at sparse position idx3 (scalar)."""
    out = dict(side)
    out["vals"] = jax.lax.dynamic_update_slice(
        side["vals"], packed["vals"].astype(side["vals"].dtype),
        (0, 0, idx3, 0))
    if "idx" in side:
        out["idx"] = jax.lax.dynamic_update_slice(
            side["idx"], packed["idx"], (0, 0, idx3, 0))
    if "scale" in side:
        out["scale"] = jax.lax.dynamic_update_slice(
            side["scale"], packed["scale"], (0, 0, idx3))
    return out


def _write_sparse_at(side: Params, packed: Params, idx_b: jnp.ndarray) -> Params:
    """Write packed single vectors [B, Kv, 1, ...] at per-sequence sparse
    positions ``idx_b`` [B] (decode: each sequence evicts its own token).
    Out-of-range positions (dead lanes park at S) are dropped."""
    B = idx_b.shape[0]
    bi = jnp.arange(B)
    out = dict(side)
    out["vals"] = side["vals"].at[bi, :, idx_b].set(
        packed["vals"][:, :, 0].astype(side["vals"].dtype), mode="drop")
    if "idx" in side:
        out["idx"] = side["idx"].at[bi, :, idx_b].set(packed["idx"][:, :, 0],
                                                      mode="drop")
    if "scale" in side:
        out["scale"] = side["scale"].at[bi, :, idx_b].set(
            packed["scale"][:, :, 0], mode="drop")
    return out


def decode_evict_winnow(cache: Params, swan, k_hat: jnp.ndarray,
                        v_hat: jnp.ndarray, pos, k_act=None):
    """Layout-independent decode-step mechanics shared by the slab and
    paged caches: pop each sequence's ring occupant (Algorithm 1's
    pop-oldest), winnow it, and stage the ring insert of the new token.

    Returns ``(write_idx [B], packed_k, packed_v, ring_updates)`` — the
    caller commits the packed vectors to ITS sparse storage at per-sequence
    position ``write_idx`` (slab: direct row; paged: page-table indirect)
    and merges ``ring_updates`` into the cache dict.  With ``b == 0``
    (paper's bt=0 ablation) the new token itself is winnowed at ``pos`` and
    there are no ring updates.  While ``old_pos < 0`` the clamped
    ``write_idx = 0`` write is garbage that validity masks hide.

    Dead lanes (``pos < 0``: free slots, and slots mid chunked-prefill —
    whose ring holds REAL tokens a garbage write must not evict) keep their
    ring state untouched; the caller must also drop their sparse write
    (slab: park ``write_idx`` out of range; paged: redirect to the trash
    page).
    """
    B = k_hat.shape[0]
    b = swan.buffer
    pos = per_seq_pos(pos, B)
    dead = pos < 0                                                  # [B]
    if b == 0:   # winnow immediately, no ring
        kt = k_hat.transpose(0, 2, 1, 3)
        vt = v_hat.transpose(0, 2, 1, 3)
        return (pos, winnow_vector(kt, swan, "k", k_act),
                winnow_vector(vt, swan, "v", k_act), {})
    bi = jnp.arange(B)
    slot = jnp.mod(pos, b)                                          # [B]
    old_pos = jnp.take_along_axis(cache["buf_pos"], slot[:, None], axis=1)[:, 0]
    write_idx = jnp.maximum(old_pos, 0)                             # [B]
    # --- evict & winnow old occupant (garbage while old_pos < 0: masked) ---
    old_k = jnp.take_along_axis(cache["buf_k"], slot[:, None, None, None], axis=2)
    old_v = jnp.take_along_axis(cache["buf_v"], slot[:, None, None, None], axis=2)
    packed_k = winnow_vector(old_k, swan, "k", k_act)
    packed_v = winnow_vector(old_v, swan, "v", k_act)
    # --- insert new token into each sequence's ring slot -------------------
    kt = k_hat.transpose(0, 2, 1, 3).astype(cache["buf_k"].dtype)   # [B,Kv,1,dh]
    vt = v_hat.transpose(0, 2, 1, 3).astype(cache["buf_v"].dtype)
    ring = {
        "buf_k": jnp.where(dead[:, None, None, None], cache["buf_k"],
                           cache["buf_k"].at[bi, :, slot].set(kt[:, :, 0])),
        "buf_v": jnp.where(dead[:, None, None, None], cache["buf_v"],
                           cache["buf_v"].at[bi, :, slot].set(vt[:, :, 0])),
        "buf_pos": jnp.where(dead[:, None], cache["buf_pos"],
                             cache["buf_pos"].at[bi, slot].set(pos)),
    }
    return write_idx, packed_k, packed_v, ring


def swan_cache_insert_decode(cache: Params, swan, cfg, k_hat: jnp.ndarray,
                             v_hat: jnp.ndarray, pos, k_act=None) -> Params:
    """One decode step: evict+winnow the ring slot's occupant, insert the new
    rotated k̂/v̂ [B, 1, Kv, dh] at position ``pos`` (scalar or [B]).  Dead
    lanes (pos < 0) are no-ops: their sparse write parks at S (dropped)."""
    write_idx, packed_k, packed_v, ring = decode_evict_winnow(
        cache, swan, k_hat, v_hat, pos, k_act)
    S = cache["k"]["vals"].shape[2]
    write_idx = jnp.where(per_seq_pos(pos, k_hat.shape[0]) >= 0,
                          write_idx, S)
    out = dict(cache)
    out.update(ring)
    out["k"] = _write_sparse_at(cache["k"], packed_k, write_idx)
    out["v"] = _write_sparse_at(cache["v"], packed_v, write_idx)
    return out


def swan_cache_insert_prefill(cache: Params, swan, cfg, k_hat: jnp.ndarray,
                              v_hat: jnp.ndarray, k_act=None,
                              true_len=None) -> Params:
    """Bulk insert a prefill of S tokens (positions 0..S-1).

    Tokens [0, S-b) are winnowed into the sparse cache; the last min(S, b)
    tokens land dense in the ring at their natural slots (t % b).

    ``true_len`` (traced scalar) supports prompt-length bucketing: S is the
    padded bucket length, only positions [0, true_len) are real.  The ring
    must then hold [true_len - b, true_len) — gathered dynamically — so the
    sparse/ring visibility partition matches an unpadded prefill exactly.
    The bulk winnow still covers the static [0, S - b): overshoot rows past
    true_len - b sit in the invalid region (>= sp_len) and are rewritten by
    decode-time evictions before ever becoming visible.
    """
    from repro.sharding.api import shard
    B, S = k_hat.shape[:2]
    b = swan.buffer
    n_sp = max(S - b, 0) if b else S
    out = dict(cache)
    kt = k_hat.transpose(0, 2, 1, 3)     # [B, Kv, S, dh]
    vt = v_hat.transpose(0, 2, 1, 3)
    # pin the pre-winnow tensors to the sparse cache's (seq over 'model')
    # sharding: the per-token top-k then computes shard-locally and the
    # packed writes stay local (§Perf cell D — removes the all-gathers
    # GSPMD otherwise inserts around the bulk winnow)
    kt = shard(kt, "kv_cache")
    vt = shard(vt, "kv_cache")
    if n_sp:
        out["k"] = _write_sparse(cache["k"],
                                 winnow_vector(kt[:, :, :n_sp], swan, "k", k_act), 0)
        out["v"] = _write_sparse(cache["v"],
                                 winnow_vector(vt[:, :, :n_sp], swan, "v", k_act), 0)
    if b == 0:
        return out
    if true_len is None:
        tail = jnp.arange(n_sp, S)
        slots = tail % b
        ring_k, ring_v = kt[:, :, n_sp:], vt[:, :, n_sp:]
        ring_pos = tail.astype(jnp.int32)
    else:
        tail = jnp.asarray(true_len, jnp.int32) - b + jnp.arange(b)
        slots = jnp.mod(tail, b)         # b consecutive ints -> all residues
        src = jnp.clip(tail, 0, S - 1)
        ring_k, ring_v = kt[:, :, src], vt[:, :, src]
        ring_pos = jnp.where(tail >= 0, tail, -1).astype(jnp.int32)
    out["buf_k"] = cache["buf_k"].at[:, :, slots].set(
        ring_k.astype(cache["buf_k"].dtype))
    out["buf_v"] = cache["buf_v"].at[:, :, slots].set(
        ring_v.astype(cache["buf_v"].dtype))
    out["buf_pos"] = cache["buf_pos"].at[:, slots].set(
        jnp.broadcast_to(ring_pos[None], (B, ring_pos.shape[0])))
    return out


def chunk_evict_winnow(cache: Params, swan, k_hat: jnp.ndarray,
                       v_hat: jnp.ndarray, start, true_len, k_act=None):
    """Bulk analogue of ``decode_evict_winnow`` for prefill CHUNKS of S
    (padded) tokens, one per lane, at absolute positions
    [start_p, start_p + true_len_p) — chunked prefill resumes a cache whose
    lane ``p`` already holds tokens [0, start_p).  ``start`` / ``true_len``
    are per-lane [B] (or scalars, broadcast): the batched concurrent
    prefill advances several slots' chunks in one executable, each resuming
    at its own offset.

    Conceptually each lane's chunk performs ``true_len`` decode-style
    insertions, each popping its ring slot's occupant.  The popped set is
    exactly positions [start - b, start + true_len - b): the first
    ``true_len`` entries of the lane's position-ordered sequence

        combined = [ring occupants at start-b .. start-1 ‖ chunk tokens]

    and the new ring holds positions [start + true_len - b, start +
    true_len) — entries [true_len, true_len + b) of the same sequence, at
    their natural slots (t % b), so the ring lands exactly where a
    monolithic ``true_len``-anchored prefill of start + true_len tokens
    would put it.

    Returns ``(dest [B], packed_k, packed_v, ring_updates)``: the caller
    commits each lane's S packed vectors CONTIGUOUSLY at sparse positions
    [dest, dest + S), dest = max(start - b, 0) (slab: ``write_sparse_rows``;
    paged: page-table indirect).  Entries past position
    start + true_len - b are not-yet-valid overshoot (bucket padding /
    future-ring tokens): every such position is rewritten — by a later
    chunk's winnow window (windows of consecutive chunks overlap-cover) or
    by its decode-time eviction — before the sparse validity frontier
    (``sparse_len``) reaches it, same mechanism as the bucketed monolithic
    prefill's overshoot.
    """
    B, S = k_hat.shape[:2]
    b = swan.buffer
    start = per_seq_pos(start, B)                        # [B]
    true_len = per_seq_pos(true_len, B)                  # [B]
    kt = k_hat.transpose(0, 2, 1, 3)                     # [B, Kv, S, dh]
    vt = v_hat.transpose(0, 2, 1, 3)
    if b == 0:   # winnow immediately, no ring
        return (start, winnow_vector(kt, swan, "k", k_act),
                winnow_vector(vt, swan, "v", k_act), {})
    # position-ordered old ring: entry j of lane p holds position
    # start_p - b + j ([start-b, start) spans every residue mod b exactly
    # once; entries with negative position read never-written slots — junk
    # skipped below)
    ring_order = jnp.mod(start[:, None] - b + jnp.arange(b)[None], b)  # [B,b]
    ord_idx = ring_order[:, None, :, None]
    comb_k = jnp.concatenate(
        [jnp.take_along_axis(cache["buf_k"], ord_idx, axis=2).astype(kt.dtype),
         kt], axis=2)                                    # [B, Kv, b+S, dh]
    comb_v = jnp.concatenate(
        [jnp.take_along_axis(cache["buf_v"], ord_idx, axis=2).astype(vt.dtype),
         vt], axis=2)
    # winnow the popped set: S entries starting at combined index
    # b - min(start, b) (skips the empty pre-sequence slots while start < b)
    # -> positions [max(start - b, 0), max(start - b, 0) + S)
    w_off = jnp.clip(b - start, 0, b)                    # [B]
    dest = jnp.maximum(start - b, 0)                     # [B]
    sel = (w_off[:, None] + jnp.arange(S)[None])[:, None, :, None]
    packed_k = winnow_vector(jnp.take_along_axis(comb_k, sel, axis=2),
                             swan, "k", k_act)
    packed_v = winnow_vector(jnp.take_along_axis(comb_v, sel, axis=2),
                             swan, "v", k_act)
    # new ring: positions end - b + j at slots (end - b + j) % b
    end = start + true_len
    tail = end[:, None] - b + jnp.arange(b)[None]        # [B, b]
    slots = jnp.mod(tail, b)
    src = (true_len[:, None] + jnp.arange(b)[None])[:, None, :, None]
    r_k = jnp.take_along_axis(comb_k, src, axis=2)       # [B, Kv, b, dh]
    r_v = jnp.take_along_axis(comb_v, src, axis=2)
    ring_pos = jnp.where(tail >= 0, tail, -1).astype(jnp.int32)
    bi = jnp.arange(B)[:, None]
    ring = {
        "buf_k": cache["buf_k"].at[bi, :, slots].set(
            r_k.swapaxes(1, 2).astype(cache["buf_k"].dtype)),
        "buf_v": cache["buf_v"].at[bi, :, slots].set(
            r_v.swapaxes(1, 2).astype(cache["buf_v"].dtype)),
        "buf_pos": cache["buf_pos"].at[bi, slots].set(ring_pos),
    }
    return dest, packed_k, packed_v, ring


def write_sparse_rows(side: Params, packed: Params, lane: jnp.ndarray,
                      dest: jnp.ndarray) -> Params:
    """Commit packed chunk vectors [P, Kv, S, ...] at rows
    [dest_p, dest_p + S) of batch lanes ``lane`` [P] — the slab commit of
    the batched chunked prefill (``chunk_evict_winnow``'s contiguous
    per-lane write, indirected by lane index).  Dead lanes park out of
    range and are dropped, as are rows past the slab (overshoot near
    max_seq)."""
    S = packed["vals"].shape[2]
    rows = dest[:, None] + jnp.arange(S)[None]           # [P, S]
    li = lane[:, None]
    out = dict(side)
    out["vals"] = side["vals"].at[li, :, rows].set(
        packed["vals"].swapaxes(1, 2).astype(side["vals"].dtype), mode="drop")
    if "idx" in side:
        out["idx"] = side["idx"].at[li, :, rows].set(
            packed["idx"].swapaxes(1, 2), mode="drop")
    if "scale" in side:
        out["scale"] = side["scale"].at[li, :, rows].set(
            packed["scale"].swapaxes(1, 2), mode="drop")
    return out


def sparse_len(swan, pos) -> jnp.ndarray:
    """Number of valid sparse entries at decode position ``pos`` (scalar or
    per-sequence [B] — shape follows ``pos``)."""
    return jnp.maximum(jnp.asarray(pos) + 1 - swan.buffer, 0)
