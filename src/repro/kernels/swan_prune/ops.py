"""Jitted wrapper for the swan_prune kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.swan_prune.swan_prune import swan_prune_pallas


@partial(jax.jit, static_argnames=("k_max", "tile", "interpret"))
def swan_prune(x, p_rot, k_max: int, tile: int = 256, interpret: bool = True):
    return swan_prune_pallas(x, p_rot, k_max, tile=tile, interpret=interpret)
