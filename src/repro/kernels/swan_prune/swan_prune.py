"""Pallas TPU kernel: SWAN winnowing — rotate + magnitude top-k + pack.

Fuses the three steps of Algorithm 1 lines 7-11 for a tile of T vectors:

  1. rotate:   x̂ = x @ P         (one [T,dh]x[dh,dh] MXU matmul)
  2. top-k:    iterative argmax over |x̂| (k VPU passes of [T,dh] work —
               TPU has no hardware sort; k·T·dh compare/select ops are
               cheap relative to the rotation matmul for k ≤ dh)
  3. pack:     vals [T,k] (x̂ at the selected dims) + idx [T,k] int8

The selection loop keeps a running "taken" mask instead of sorting —
deterministic ties (lowest index wins, matching jax.lax.top_k) so the
kernel is bit-compatible with the pure-JAX reference path.

Grid: (B, Kv, S/T).  Tile defaults T=256: x tile 128 KB + P 64 KB + outputs
≈ 96 KB — far under VMEM limits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _prune_kernel(x_ref, p_ref, vals_ref, idx_ref, *, t: int, dh: int,
                  k_max: int):
    x = x_ref[0, 0].astype(jnp.float32)            # [T, dh]
    P = p_ref[0].astype(jnp.float32)               # [dh, dh]
    xh = jax.lax.dot_general(x, P, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    mag = jnp.abs(xh)
    iota = jax.lax.broadcasted_iota(jnp.int32, (t, dh), 1)

    def body(j, carry):
        mag_live, vals, idx = carry
        mx = mag_live.max(axis=1, keepdims=True)                  # [T,1]
        # lowest index among maxima (deterministic, matches lax.top_k)
        is_max = mag_live == mx
        sel = jnp.min(jnp.where(is_max, iota, dh), axis=1, keepdims=True)
        chosen = iota == sel                                       # [T,dh]
        v = jnp.sum(jnp.where(chosen, xh, 0.0), axis=1, keepdims=True)
        vals = jax.lax.dynamic_update_slice(vals, v, (0, j))
        idx = jax.lax.dynamic_update_slice(idx, sel, (0, j))
        mag_live = jnp.where(chosen, -1.0, mag_live)
        return mag_live, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0, k_max, body,
        (mag, jnp.zeros((t, k_max), jnp.float32),
         jnp.zeros((t, k_max), jnp.int32)))
    vals_ref[0, 0] = vals.astype(vals_ref.dtype)
    idx_ref[0, 0] = idx.astype(jnp.int8)


def swan_prune_pallas(x, p_rot, k_max: int, *, tile: int = 256,
                      interpret: bool = True):
    """x [B,Kv,S,dh] (post-RoPE k or v), p_rot [Kv,dh,dh] ->
    (vals [B,Kv,S,k_max] x.dtype, idx [B,Kv,S,k_max] int8)."""
    B, Kv, S, dh = x.shape
    t = min(tile, S)
    assert S % t == 0, (S, t)
    kernel = functools.partial(_prune_kernel, t=t, dh=dh, k_max=k_max)
    return pl.pallas_call(
        kernel,
        grid=(B, Kv, S // t),
        in_specs=[
            pl.BlockSpec((1, 1, t, dh), lambda b, j, s: (b, j, s, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, j, s: (j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, t, k_max), lambda b, j, s: (b, j, s, 0)),
            pl.BlockSpec((1, 1, t, k_max), lambda b, j, s: (b, j, s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Kv, S, k_max), x.dtype),
            jax.ShapeDtypeStruct((B, Kv, S, k_max), jnp.int8),
        ],
        interpret=interpret,
    )(x, p_rot)
