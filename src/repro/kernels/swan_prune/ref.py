"""Pure-jnp oracle for swan_prune: rotate via einsum + lax.top_k pack."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swan_prune_reference(x, p_rot, k_max: int):
    """x [B,Kv,S,dh], p_rot [Kv,dh,dh] -> (vals, idx int8)."""
    xh = jnp.einsum("bjsd,jde->bjse", x.astype(jnp.float32),
                    p_rot.astype(jnp.float32))
    _, idx = jax.lax.top_k(jnp.abs(xh), k_max)
    vals = jnp.take_along_axis(xh, idx, axis=-1)
    return vals.astype(x.dtype), idx.astype(jnp.int8)
