"""Kernel dispatch policy shared by every Pallas wrapper and the serve
engine (documented in docs/kernels.md).

Two independent knobs:

  * ``use_pallas`` — WHICH implementation runs (fused Pallas kernel vs
    pure-JAX/XLA).  The engine default is backend-driven: on TPU the
    kernels are the fast path; elsewhere the pure-JAX path is usually
    faster, but the kernels still RUN anywhere via interpret mode (that is
    how CPU CI validates them).
  * ``interpret`` — HOW a Pallas call executes.  ``None`` resolves from
    ``jax.default_backend()``: compiled on TPU, interpreter everywhere
    else.  Callers only pass an explicit bool in tests.

``pallas_decode_supported`` is the static eligibility gate: the fused
kernels cover the paper-faithful ``topk`` mode with a non-empty dense ring
(``truncate`` is a dense low-rank matmul XLA already schedules optimally,
and the bt=0 ablation has no ring tile to block-spec).  Sequence-dim
sharding (split-S flash-decoding) keeps the pure-JAX shard_map path — the
kernel is lane-local and composes with BATCH sharding only.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["resolve_interpret", "resolve_use_pallas",
           "pallas_decode_supported"]


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Pallas execution mode: compiled on TPU, interpreter elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def resolve_use_pallas(use_pallas: Optional[bool] = None) -> bool:
    """Engine default for the kernel-vs-XLA dispatch: auto on TPU."""
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return bool(use_pallas)


def pallas_decode_supported(swan) -> bool:
    """Static (config-level) eligibility of the fused SWAN kernels."""
    return (swan is not None and getattr(swan, "enabled", False)
            and swan.mode == "topk" and swan.buffer > 0)
