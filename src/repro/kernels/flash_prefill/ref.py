"""Pure-jnp oracle for flash_prefill: dense causal attention with GQA."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_reference(q, k, v, causal: bool = True):
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    ke = jnp.repeat(k, G, axis=2)
    ve = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   ke.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, ve.astype(jnp.float32))
    return o.astype(q.dtype)
