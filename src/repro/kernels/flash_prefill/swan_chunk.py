"""Pallas TPU kernel: bulk chunk-prefill reads of the packed SWAN cache.

The chunked-prefill attention (`swan_chunk_prefill_attention`) splits per
query into [winnowed sparse prefix ‖ ring ‖ chunk]; the sparse-prefix part
is the bandwidth-bound bulk read this kernel fuses.  Each grid step DMAs
one packed tile (vals [BS,k] + idx int8, optionally int8 vals + f32
scales), expands it ONCE in VMEM via the same one-hot fori-loop as the
decode kernel, and runs all Q = S_chunk·G chunk queries against it through
two MXU matmuls with online-softmax scratch carried across tiles — the
multi-query analogue of ``swan_decode``.  The pure-JAX fallback
(`_sparse_stats_bulk`) expands into an HBM transient instead.

Outputs are MERGEABLE partial stats (m_safe [B,Kv,Q], l [B,Kv,Q],
o_unnorm [B,Kv,Q,dh], all f32): the dense [ring ‖ chunk] side and the
exact merge stay outside (they touch fresh chunk tensors, not the cache).
``m_safe`` follows the `_sparse_stats_bulk` convention — 0.0 where a lane
saw no valid sparse position (empty prefix / dead lane), so the outer
merge is bit-compatible with the pure-JAX stats.

Grid: (B, Kv, S/BS) slab, (B, Kv, Pg) paged — the sequence axis innermost
so scratch carries.  The paged variant takes each lane's page-table row as
a scalar-prefetch operand and gathers pool pages directly into VMEM tiles
(no materialised logical view), exactly like ``swan_decode_paged_pallas``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
LANE_WIDTH = 128
SUBLANE_F32 = 8


def vmem_footprint(*, bs: int, dh: int, k_max: int, Q: int,
                   quantized: bool = False) -> int:
    """Per-grid-step VMEM working set in bytes (double-buffered inputs),
    mirroring the BlockSpecs in ``swan_chunk_stats_pallas``."""
    vals_b = 4 if not quantized else 1
    tile = 2 * (bs * k_max * vals_b + bs * k_max)     # k/v packed vals+idx
    if quantized:
        tile += 2 * bs * 4                            # k/v scales
    tile += Q * dh * 4                                # q block (resident)
    inputs = 2 * tile                                 # double buffering
    expand = 2 * bs * dh * 4                          # k_dense + v_dense
    scratch = 2 * Q * 4 + Q * dh * 4                  # m, l, acc
    out = 2 * Q * 4 + Q * dh * 4                      # m, l, o
    return inputs + expand + scratch + out


def precheck(*, B: int, Kv: int, Q: int, dh: int, S: int, k_max: int,
             block_s: int = 256, quantized: bool = False,
             vmem_budget: int = VMEM_BYTES_PER_CORE) -> dict:
    """Static grid/VMEM validation for the bulk-chunk stats kernel — same
    contract as ``repro.kernels.swan_decode.precheck``.  For the paged
    variant pass ``S = Pg * page_size`` and ``block_s = page_size``."""
    errors, warnings = [], []
    bs = min(block_s, S) if S else 0
    if S <= 0:
        errors.append(f"empty sparse extent S={S}: caller must short-"
                      "circuit to zero stats")
    elif bs <= 0 or S % bs:
        errors.append(f"sparse length S={S} not divisible by block bs={bs}")
    if k_max > dh:
        errors.append(f"k_max={k_max} exceeds dh={dh}: one-hot expansion "
                      "would scatter out of range")
    vmem = vmem_footprint(bs=max(bs, 1), dh=dh, k_max=k_max, Q=Q,
                          quantized=quantized)
    if vmem > vmem_budget:
        errors.append(f"VMEM working set {vmem} B exceeds budget "
                      f"{vmem_budget} B (bs={bs}, k={k_max}, dh={dh}, Q={Q})")
    if dh % LANE_WIDTH:
        warnings.append(f"dh={dh} not a multiple of lane width "
                        f"{LANE_WIDTH}: tiles pad to 128 lanes")
    if Q % SUBLANE_F32 or (bs and bs % SUBLANE_F32):
        warnings.append(f"Q={Q}/bs={bs} not multiples of f32 sublane "
                        f"{SUBLANE_F32}: tiles pad sublanes")
    return {"errors": errors, "warnings": warnings, "vmem_bytes": vmem}


def _expand_packed(vals, idx, bs: int, dh: int, k_max: int):
    iota = jax.lax.broadcasted_iota(jnp.int32, (bs, dh), 1)

    def body(j, acc):
        v = jax.lax.dynamic_slice(vals, (0, j), (bs, 1))
        i = jax.lax.dynamic_slice(idx, (0, j), (bs, 1))
        return acc + v * (iota == i).astype(jnp.float32)

    return jax.lax.fori_loop(0, k_max, body,
                             jnp.zeros((bs, dh), jnp.float32))


def _chunk_stats_body(meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref,
                      ks_ref, vs_ref, mo_ref, lo_ref, oo_ref,
                      m_sc, l_sc, acc_sc, *, bs: int, dh: int, k_max: int,
                      n_sblocks: int, quantized: bool):
    sb = pl.program_id(2)
    Q = q_ref.shape[2]
    scale = 1.0 / math.sqrt(dh)
    sp_len = meta_ref[0, 0]       # this lane's valid sparse-prefix length

    @pl.when(sb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)                        # [Q, dh]
    kv = kv_ref[0, 0].astype(jnp.float32)                      # [BS, k]
    vv = vv_ref[0, 0].astype(jnp.float32)
    if quantized:
        kv = kv * ks_ref[0, 0][:, None]
        vv = vv * vs_ref[0, 0][:, None]
    ki = ki_ref[0, 0].astype(jnp.int32)
    vi = vi_ref[0, 0].astype(jnp.int32)
    k_dense = _expand_packed(kv, ki, bs, dh, k_max)            # [BS, dh]
    v_dense = _expand_packed(vv, vi, bs, dh, k_max)

    s = jax.lax.dot_general(q, k_dense, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    t_pos = sb * bs + jax.lax.broadcasted_iota(jnp.int32, (Q, bs), 1)
    s = jnp.where(t_pos < sp_len, s, NEG_INF)

    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(t_pos < sp_len, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p, v_dense, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(sb == n_sblocks - 1)
    def _write():
        m = m_sc[...]
        # empty-prefix convention of _sparse_stats_bulk: m_safe = 0.0 when
        # no position was valid (all scores stayed at the NEG_INF floor)
        m_safe = jnp.where(m > NEG_INF * 0.5, m, 0.0)
        mo_ref[0, 0] = m_safe[:, 0]
        lo_ref[0, 0] = l_sc[...][:, 0]
        oo_ref[0, 0] = acc_sc[...]


def _chunk_kernel(*refs, quantized: bool, **static):
    """Positional-ref adapter for the optional scale operands."""
    meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref = refs[:6]
    i = 6
    if quantized:
        ks_ref, vs_ref = refs[i:i + 2]
        i += 2
    else:
        ks_ref = vs_ref = None
    mo_ref, lo_ref, oo_ref, m_sc, l_sc, acc_sc = refs[i:i + 6]
    _chunk_stats_body(meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref,
                      ks_ref, vs_ref, mo_ref, lo_ref, oo_ref,
                      m_sc, l_sc, acc_sc, quantized=quantized, **static)


def _paged_chunk_kernel(tab_ref, *refs, quantized: bool, **static):
    """Scalar-prefetch adapter: the page-table row feeds index maps only."""
    _chunk_kernel(*refs, quantized=quantized, **static)


def _stats_out(B: int, Kv: int, Q: int, dh: int, paged: bool):
    """(out_specs, out_shape) for the three stats outputs."""
    if paged:
        m_map = lambda b_, j, s, tab: (b_, j, 0)          # noqa: E731
        o_map = lambda b_, j, s, tab: (b_, j, 0, 0)       # noqa: E731
    else:
        m_map = lambda b_, j, s: (b_, j, 0)               # noqa: E731
        o_map = lambda b_, j, s: (b_, j, 0, 0)            # noqa: E731
    specs = [pl.BlockSpec((1, 1, Q), m_map),
             pl.BlockSpec((1, 1, Q), m_map),
             pl.BlockSpec((1, 1, Q, dh), o_map)]
    shapes = (jax.ShapeDtypeStruct((B, Kv, Q), jnp.float32),
              jax.ShapeDtypeStruct((B, Kv, Q), jnp.float32),
              jax.ShapeDtypeStruct((B, Kv, Q, dh), jnp.float32))
    return specs, shapes


_SCRATCH = lambda Q, dh: [pltpu.VMEM((Q, 1), jnp.float32),    # noqa: E731
                          pltpu.VMEM((Q, 1), jnp.float32),
                          pltpu.VMEM((Q, dh), jnp.float32)]


def swan_chunk_stats_pallas(q, k_vals, k_idx, v_vals, v_idx, sp_len,
                            k_scale=None, v_scale=None, *,
                            block_s: int = 256,
                            interpret: Optional[bool] = None):
    """q [B,Kv,Q,dh] (Q = S_chunk·G flattened queries); packed sparse
    [B,Kv,S,k]; per-lane ``sp_len [B]``.  Returns (m_safe [B,Kv,Q],
    l [B,Kv,Q], o_unnorm [B,Kv,Q,dh]) — drop-in for
    ``swan_attention._sparse_stats_bulk``."""
    from repro.kernels.dispatch import resolve_interpret
    B, Kv, Q, dh = q.shape
    S, k_max = k_vals.shape[2], k_vals.shape[3]
    bs = min(block_s, S)
    assert S > 0 and S % bs == 0, (S, bs)
    n_sblocks = S // bs
    quantized = k_scale is not None
    meta = jnp.broadcast_to(jnp.asarray(sp_len, jnp.int32),
                            (B,)).reshape(B, 1)

    kernel = functools.partial(_chunk_kernel, bs=bs, dh=dh, k_max=k_max,
                               n_sblocks=n_sblocks, quantized=quantized)
    specs = [
        pl.BlockSpec((1, 1), lambda b_, j, s: (b_, 0)),                # meta
        pl.BlockSpec((1, 1, Q, dh), lambda b_, j, s: (b_, j, 0, 0)),   # q
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),
    ]
    operands = [meta, q, k_vals, k_idx, v_vals, v_idx]
    if quantized:
        specs += [pl.BlockSpec((1, 1, bs), lambda b_, j, s: (b_, j, s)),
                  pl.BlockSpec((1, 1, bs), lambda b_, j, s: (b_, j, s))]
        operands += [k_scale, v_scale]
    out_specs, out_shape = _stats_out(B, Kv, Q, dh, paged=False)
    return pl.pallas_call(
        kernel,
        grid=(B, Kv, n_sblocks),
        in_specs=specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=_SCRATCH(Q, dh),
        interpret=resolve_interpret(interpret),
    )(*operands)


def swan_chunk_stats_paged_pallas(q, pool_k_vals, pool_k_idx, pool_v_vals,
                                  pool_v_idx, sp_len, page_rows,
                                  pool_k_scale=None, pool_v_scale=None, *,
                                  interpret: Optional[bool] = None):
    """Paged bulk-chunk stats: pool sides [n_pages,Kv,ps,k] + per-lane
    ``page_rows [B,Pg]`` gathered into VMEM tiles inside the kernel —
    the chunk path's replacement for ``paged_logical_view`` +
    ``_sparse_stats_bulk``."""
    from repro.kernels.dispatch import resolve_interpret
    B, Kv, Q, dh = q.shape
    _, _, ps, k_max = pool_k_vals.shape
    Pg = page_rows.shape[1]
    assert page_rows.shape == (B, Pg), page_rows.shape
    assert Pg >= 1, "empty page-table prefix: caller must short-circuit"
    quantized = pool_k_scale is not None
    meta = jnp.broadcast_to(jnp.asarray(sp_len, jnp.int32),
                            (B,)).reshape(B, 1)

    kernel = functools.partial(_paged_chunk_kernel, bs=ps, dh=dh,
                               k_max=k_max, n_sblocks=Pg,
                               quantized=quantized)
    tile = lambda b_, j, s, tab: (tab[b_, s], j, 0, 0)     # noqa: E731
    specs = [
        pl.BlockSpec((1, 1), lambda b_, j, s, tab: (b_, 0)),           # meta
        pl.BlockSpec((1, 1, Q, dh), lambda b_, j, s, tab: (b_, j, 0, 0)),
        pl.BlockSpec((1, 1, ps, k_max), tile),
        pl.BlockSpec((1, 1, ps, k_max), tile),
        pl.BlockSpec((1, 1, ps, k_max), tile),
        pl.BlockSpec((1, 1, ps, k_max), tile),
    ]
    operands = [meta, q, pool_k_vals, pool_k_idx, pool_v_vals, pool_v_idx]
    if quantized:
        sc = lambda b_, j, s, tab: (tab[b_, s], j, 0)      # noqa: E731
        specs += [pl.BlockSpec((1, 1, ps), sc), pl.BlockSpec((1, 1, ps), sc)]
        operands += [pool_k_scale, pool_v_scale]
    out_specs, out_shape = _stats_out(B, Kv, Q, dh, paged=True)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Kv, Pg),
        in_specs=specs,
        out_specs=out_specs,
        scratch_shapes=_SCRATCH(Q, dh),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(page_rows, *operands)
