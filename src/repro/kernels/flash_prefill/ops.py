"""Jitted wrappers for the flash_prefill kernels.

``interpret=None`` resolves from the backend (``repro.kernels.dispatch``):
compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.dispatch import resolve_interpret
from repro.kernels.flash_prefill.flash_prefill import flash_attention_pallas
from repro.kernels.flash_prefill.swan_chunk import (
    swan_chunk_stats_paged_pallas, swan_chunk_stats_pallas)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: Optional[bool] = None):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k,
                                  interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def swan_chunk_stats(q, k_vals, k_idx, v_vals, v_idx, sp_len,
                     k_scale=None, v_scale=None, block_s: int = 256,
                     interpret: Optional[bool] = None):
    return swan_chunk_stats_pallas(q, k_vals, k_idx, v_vals, v_idx, sp_len,
                                   k_scale=k_scale, v_scale=v_scale,
                                   block_s=block_s,
                                   interpret=resolve_interpret(interpret))
