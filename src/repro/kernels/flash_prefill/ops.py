"""Jitted wrapper for the flash_prefill kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_prefill.flash_prefill import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
