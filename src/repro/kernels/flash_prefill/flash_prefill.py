"""Pallas TPU kernel: causal flash attention (prefill), GQA-aware.

Grid (B, H, nQ, nK) with the K axis innermost; online-softmax stats live in
VMEM scratch across K steps.  Causality is exploited structurally: K blocks
strictly above the diagonal contribute nothing and are skipped via
``pl.when`` (their DMA still lands but the MXU work is saved; on real TPU
a dynamic grid bound would also skip the DMA).

BlockSpecs: q/o tiles [BQ, dh], kv tiles [BK, dh] with the KV head index
derived as h // G (GQA: query heads share KV tiles — the kernel reads each
KV tile G times but from the much smaller kv-head array).  dh=128 = lane
width; BQ/BK default 256 ≈ 512 KB/tile f32 — VMEM-safe with double
buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
LANE_WIDTH = 128
SUBLANE_F32 = 8


def vmem_footprint(*, bq: int, bk: int, dh: int) -> int:
    """Per-grid-step VMEM working set in bytes (double-buffered q/k/v
    tiles + online-softmax scratch + output tile), matching the BlockSpecs
    in ``flash_attention_pallas``."""
    inputs = 2 * (bq * dh + 2 * bk * dh) * 4          # q + k/v, double-buf
    scratch = 2 * bq * 4 + bq * dh * 4                # m, l, acc
    out = bq * dh * 4
    return inputs + scratch + out


def precheck(*, B: int, H: int, Kv: int, Sq: int, Sk: int, dh: int,
             block_q: int = 256, block_k: int = 256,
             vmem_budget: int = VMEM_BYTES_PER_CORE) -> dict:
    """Static grid/VMEM validation for ``flash_attention_pallas`` —
    same contract as ``repro.kernels.swan_decode.precheck``."""
    errors, warnings = [], []
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if bq <= 0 or Sq % bq:
        errors.append(f"Sq={Sq} not divisible by query block bq={bq}")
    if bk <= 0 or Sk % bk:
        errors.append(f"Sk={Sk} not divisible by key block bk={bk}")
    if Kv <= 0 or H % Kv:
        errors.append(f"H={H} not divisible by Kv={Kv}: GQA head-group "
                      "index h // G would misalign KV tiles")
    vmem = vmem_footprint(bq=bq, bk=bk, dh=dh)
    if vmem > vmem_budget:
        errors.append(f"VMEM working set {vmem} B exceeds budget "
                      f"{vmem_budget} B (bq={bq}, bk={bk}, dh={dh})")
    if dh % LANE_WIDTH:
        warnings.append(f"dh={dh} not a multiple of lane width "
                        f"{LANE_WIDTH}: tiles pad to 128 lanes")
    if bq % SUBLANE_F32 or bk % SUBLANE_F32:
        warnings.append(f"bq={bq}/bk={bk} not multiples of f32 sublane "
                        f"{SUBLANE_F32}: tiles pad sublanes")
    return {"errors": errors, "warnings": warnings, "vmem_bytes": vmem}


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc,
                  *, bq: int, bk: int, dh: int, n_kblocks: int, causal: bool):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    scale = 1.0 / math.sqrt(dh)

    @pl.when(kb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    run = (kb * bk <= qb * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)                 # [BQ, dh]
        k = k_ref[0, 0].astype(jnp.float32)                 # [BK, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = k_pos <= q_pos
            s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(kb == n_kblocks - 1)
    def _write():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret=None):
    """q [B,Sq,H,dh]; k/v [B,Sk,Kv,dh] -> o [B,Sq,H,dh] (GQA-aware).
    ``interpret=None`` resolves from the backend (repro.kernels.dispatch)."""
    from repro.kernels.dispatch import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Sq, H, dh = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    qt = q.transpose(0, 2, 1, 3)      # [B, H, Sq, dh]
    kt = k.transpose(0, 2, 1, 3)      # [B, Kv, Sk, dh]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, dh=dh,
                               n_kblocks=nk, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
