"""Pallas TPU kernel: SWAN hybrid-cache decode attention.

The kernel consumes the *compressed* cache directly (paper's
"decompression-free" claim, TPU-native): each grid step DMAs one packed
sparse tile (vals [BS,k] + idx [BS,k] int8, optionally int8 vals + f32
scales) from HBM into VMEM, expands it **in registers** via a one-hot
fori-loop (never materialising a dense cache in HBM), and feeds two MXU
matmuls (scores, weighted values) through a flash-style online-softmax
accumulator held in VMEM scratch.  The final grid step folds in the dense
ring buffer.

Grid: (B, Kv, S/BS) — the sequence axis iterates innermost so the scratch
accumulators carry across sparse tiles.

VMEM budget per step (defaults BS=256, k≤128, dh=128, f32):
  packed tiles 2·(BS·k·4 + BS·k) ≈ 640 KB, expansion buffer BS·dh·4 =
  128 KB, buffer tile b·dh·4 ≈ 64 KB, accumulators G·dh·4 — comfortably
  inside the ~16 MB v5e VMEM with headroom for double buffering.
dh=128 matches the lane width; BS is sublane-aligned; the j-loop expansion
is VPU work that overlaps the HBM-bound tile streaming (decode is
bandwidth-bound, so these FLOPs are free — DESIGN.md §2).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# ~16 MB/core on v4/v5e; the precheck budgets against this by default
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
LANE_WIDTH = 128        # last-dim tiling unit
SUBLANE_F32 = 8         # second-to-last-dim tiling unit for f32


def vmem_footprint(*, bs: int, dh: int, k_max: int, G: int, b: int,
                   quantized: bool = False) -> int:
    """Per-grid-step VMEM working set in bytes, double-buffered inputs.

    Mirrors the BlockSpecs in ``swan_decode_pallas`` plus the in-register
    expansion buffers and scratch accumulators — the static half of the
    docstring's budget paragraph, so the swanlint auditor (and tests) can
    reject a (block_s, k, dh, buffer) configuration before lowering."""
    vals_b = 4 if not quantized else 1          # f32 vals vs int8+scale
    tile = 2 * (bs * k_max * vals_b + bs * k_max)     # k/v packed vals+idx
    tile += 2 * bs * 4                                # k/v scales
    tile += G * dh * 4                                # q tile
    tile += 2 * b * dh * 4 + b * 4                    # ring buffer k/v + pos
    inputs = 2 * tile                                 # double buffering
    expand = 2 * bs * dh * 4                          # k_dense + v_dense
    scratch = 2 * G * 4 + G * dh * 4                  # m, l, acc
    out = G * dh * 4
    return inputs + expand + scratch + out


def precheck(*, B: int, Kv: int, G: int, dh: int, S: int, k_max: int,
             b: int, block_s: int = 256, quantized: bool = False,
             vmem_budget: int = VMEM_BYTES_PER_CORE) -> dict:
    """Static grid/VMEM validation for ``swan_decode_pallas``.

    Returns ``{"errors": [...], "warnings": [...], "vmem_bytes": int}``;
    errors are conditions under which the kernel asserts or cannot fit,
    warnings are perf hazards (sub-lane-width dims pad and waste MXU/VPU
    lanes — fine for smoke configs, wrong for production shapes)."""
    errors, warnings = [], []
    bs = min(block_s, S)
    if bs <= 0 or S % bs:
        errors.append(f"sparse length S={S} not divisible by block bs={bs}")
    if k_max > dh:
        errors.append(f"k_max={k_max} exceeds dh={dh}: one-hot expansion "
                      "would scatter out of range")
    vmem = vmem_footprint(bs=bs, dh=dh, k_max=k_max, G=G, b=b,
                          quantized=quantized)
    if vmem > vmem_budget:
        errors.append(f"VMEM working set {vmem} B exceeds budget "
                      f"{vmem_budget} B (bs={bs}, k={k_max}, dh={dh}, b={b})")
    if dh % LANE_WIDTH:
        warnings.append(f"dh={dh} not a multiple of lane width "
                        f"{LANE_WIDTH}: tiles pad to 128 lanes")
    if bs % SUBLANE_F32:
        warnings.append(f"bs={bs} not a multiple of f32 sublane "
                        f"{SUBLANE_F32}: tiles pad sublanes")
    return {"errors": errors, "warnings": warnings, "vmem_bytes": vmem}


def _expand_packed(vals, idx, bs: int, dh: int, k_max: int):
    """One-hot in-register expansion: [BS,k] (+idx) -> dense [BS,dh] f32."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (bs, dh), 1)

    def body(j, acc):
        v = jax.lax.dynamic_slice(vals, (0, j), (bs, 1))       # [BS,1]
        i = jax.lax.dynamic_slice(idx, (0, j), (bs, 1))
        return acc + v * (iota == i).astype(jnp.float32)

    return jax.lax.fori_loop(0, k_max, body,
                             jnp.zeros((bs, dh), jnp.float32))


def _swan_decode_body(meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref,
                      ks_ref, vs_ref, bk_ref, bv_ref, bp_ref, o_ref,
                      m_sc, l_sc, acc_sc, *, bs: int, dh: int, k_max: int,
                      n_sblocks: int, quantized: bool):
    sb = pl.program_id(2)
    G = q_ref.shape[2]
    scale = 1.0 / math.sqrt(dh)
    pos = meta_ref[0, 0]          # this sequence's decode position
    sp_len = meta_ref[0, 1]       # this sequence's valid sparse length

    @pl.when(sb == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)                        # [G, dh]

    # ---- sparse tile ------------------------------------------------------
    kv = kv_ref[0, 0].astype(jnp.float32)                      # [BS, k]
    vv = vv_ref[0, 0].astype(jnp.float32)
    if quantized:
        kv = kv * ks_ref[0, 0][:, None]
        vv = vv * vs_ref[0, 0][:, None]
    ki = ki_ref[0, 0].astype(jnp.int32)
    vi = vi_ref[0, 0].astype(jnp.int32)
    k_dense = _expand_packed(kv, ki, bs, dh, k_max)            # [BS, dh]
    v_dense = _expand_packed(vv, vi, bs, dh, k_max)

    s = jax.lax.dot_general(q, k_dense, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    t_pos = sb * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
    s = jnp.where(t_pos < sp_len, s, NEG_INF)

    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(t_pos < sp_len, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p, v_dense, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    # ---- final step: dense ring buffer + write-out -------------------------
    @pl.when(sb == n_sblocks - 1)
    def _finalize():
        bk = bk_ref[0, 0].astype(jnp.float32)                  # [b, dh]
        bv = bv_ref[0, 0].astype(jnp.float32)
        bpos = bp_ref[0]                                       # [b] (this seq)
        s_b = jax.lax.dot_general(q, bk, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * scale
        valid = (bpos >= 0) & (bpos <= pos)
        s_b = jnp.where(valid[None, :], s_b, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_fin = jnp.maximum(m_prev, s_b.max(axis=1, keepdims=True))
        p_b = jnp.where(valid[None, :], jnp.exp(s_b - m_fin), 0.0)
        corr = jnp.exp(m_prev - m_fin)
        l_fin = l_prev * corr + p_b.sum(axis=1, keepdims=True)
        acc = acc_sc[...] * corr + jax.lax.dot_general(
            p_b, bv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0, 0] = (acc / jnp.maximum(l_fin, 1e-30)).astype(o_ref.dtype)


def _decode_kernel(*refs, quantized: bool, **static):
    """Positional-ref adapter: the scale refs exist only for quantized
    caches (dummy f32 scale streams would double the packed-tile HBM
    traffic for nothing), so the pallas_call operand list — and hence the
    kernel signature — is built conditionally."""
    meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref = refs[:6]
    i = 6
    if quantized:
        ks_ref, vs_ref = refs[i:i + 2]
        i += 2
    else:
        ks_ref = vs_ref = None
    bk_ref, bv_ref, bp_ref, o_ref, m_sc, l_sc, acc_sc = refs[i:i + 7]
    _swan_decode_body(meta_ref, q_ref, kv_ref, ki_ref, vv_ref, vi_ref,
                      ks_ref, vs_ref, bk_ref, bv_ref, bp_ref, o_ref,
                      m_sc, l_sc, acc_sc, quantized=quantized, **static)


def _decode_meta(pos, sp_len, B: int):
    return jnp.stack([
        jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,)),
        jnp.broadcast_to(jnp.asarray(sp_len, jnp.int32), (B,)),
    ], axis=1)                                                 # [B, 2]


def swan_decode_pallas(q, k_vals, k_idx, v_vals, v_idx, buf_k, buf_v,
                       buf_pos, pos, sp_len, k_scale=None, v_scale=None,
                       *, block_s: int = 256,
                       interpret: Optional[bool] = None):
    """q [B,Kv,G,dh]; packed sparse [B,Kv,S,k]; buffer [B,Kv,b,dh];
    buf_pos [B,b].  ``pos``/``sp_len`` are scalars or per-sequence [B]
    (continuous batching: each sequence masks its own ring + sparse prefix).

    Returns o [B,Kv,G,dh].  ``interpret=None`` resolves from the backend
    (compiled on TPU, interpreter elsewhere — repro.kernels.dispatch).
    """
    from repro.kernels.dispatch import resolve_interpret
    B, Kv, G, dh = q.shape
    S, k_max = k_vals.shape[2], k_vals.shape[3]
    b = buf_k.shape[2]
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    assert buf_pos.shape == (B, b), buf_pos.shape
    n_sblocks = S // bs
    quantized = k_scale is not None
    meta = _decode_meta(pos, sp_len, B)

    kernel = functools.partial(
        _decode_kernel, bs=bs, dh=dh, k_max=k_max,
        n_sblocks=n_sblocks, quantized=quantized)
    grid = (B, Kv, n_sblocks)
    specs = [
        pl.BlockSpec((1, 2), lambda b_, j, s: (b_, 0)),                # meta
        pl.BlockSpec((1, 1, G, dh), lambda b_, j, s: (b_, j, 0, 0)),   # q
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),  # k_vals
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),  # k_idx
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),  # v_vals
        pl.BlockSpec((1, 1, bs, k_max), lambda b_, j, s: (b_, j, s, 0)),  # v_idx
    ]
    operands = [meta, q, k_vals, k_idx, v_vals, v_idx]
    if quantized:
        specs += [
            pl.BlockSpec((1, 1, bs), lambda b_, j, s: (b_, j, s)),     # k_scale
            pl.BlockSpec((1, 1, bs), lambda b_, j, s: (b_, j, s)),     # v_scale
        ]
        operands += [k_scale, v_scale]
    specs += [
        pl.BlockSpec((1, 1, b, dh), lambda b_, j, s: (b_, j, 0, 0)),   # buf_k
        pl.BlockSpec((1, 1, b, dh), lambda b_, j, s: (b_, j, 0, 0)),   # buf_v
        pl.BlockSpec((1, b), lambda b_, j, s: (b_, 0)),                # buf_pos
    ]
    operands += [buf_k, buf_v, buf_pos]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b_, j, s: (b_, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, dh), jnp.float32),  # acc
        ],
        interpret=resolve_interpret(interpret),
    )(*operands)


def _paged_decode_kernel(tab_ref, *refs, quantized: bool, **static):
    """Scalar-prefetch adapter: ``tab_ref`` (the page-table prefix) is
    consumed by the BlockSpec index maps only — the body sees exactly the
    slab tile layout (VMEM tiles don't care which HBM page they came
    from)."""
    _decode_kernel(*refs, quantized=quantized, **static)


def swan_decode_paged_pallas(q, pool_k_vals, pool_k_idx, pool_v_vals,
                             pool_v_idx, buf_k, buf_v, buf_pos, pos, sp_len,
                             page_tab, pool_k_scale=None, pool_v_scale=None,
                             *, interpret: Optional[bool] = None):
    """Paged-pool decode: the packed sparse sides live in a shared page
    pool ``[n_pages, Kv, ps, k]`` and each sequence's logical prefix is
    named by ``page_tab [B, Pg]`` (a power-of-two table prefix, unmapped
    entries -> trash page 0).

    The gather happens INSIDE the kernel: ``page_tab`` rides as a
    scalar-prefetch operand (SMEM, shipped before the grid runs) and the
    pool BlockSpec index maps read it — grid step (b, j, s) DMAs physical
    page ``page_tab[b, s]`` straight into the VMEM tile.  No
    ``[B, Pg*ps, k]`` logical view is ever materialised in HBM (that XLA
    gather is exactly the re-inflation `paged_logical_view` pays on the
    pure-JAX path).  Trash-page tiles DMA garbage that the per-sequence
    ``sp_len`` mask zeroes: logical positions >= sp_len are masked no
    matter what physical page backs them.

    Returns o [B,Kv,G,dh] — same contract as ``swan_decode_pallas`` over
    ``paged_logical_view``.
    """
    from repro.kernels.dispatch import resolve_interpret
    B, Kv, G, dh = q.shape
    n_pages, _, ps, k_max = pool_k_vals.shape
    b = buf_k.shape[2]
    Pg = page_tab.shape[1]
    assert page_tab.shape == (B, Pg), page_tab.shape
    assert Pg >= 1, "empty page-table prefix: caller must ship >= 1 page"
    assert buf_pos.shape == (B, b), buf_pos.shape
    quantized = pool_k_scale is not None
    meta = _decode_meta(pos, sp_len, B)

    kernel = functools.partial(
        _paged_decode_kernel, bs=ps, dh=dh, k_max=k_max,
        n_sblocks=Pg, quantized=quantized)
    # NOTE index-map signatures: (grid indices..., scalar-prefetch refs...)
    specs = [
        pl.BlockSpec((1, 2), lambda b_, j, s, tab: (b_, 0)),            # meta
        pl.BlockSpec((1, 1, G, dh), lambda b_, j, s, tab: (b_, j, 0, 0)),  # q
        # pool tiles: the paged VMEM gather — physical page from the table
        pl.BlockSpec((1, 1, ps, k_max),
                     lambda b_, j, s, tab: (tab[b_, s], j, 0, 0)),      # k_vals
        pl.BlockSpec((1, 1, ps, k_max),
                     lambda b_, j, s, tab: (tab[b_, s], j, 0, 0)),      # k_idx
        pl.BlockSpec((1, 1, ps, k_max),
                     lambda b_, j, s, tab: (tab[b_, s], j, 0, 0)),      # v_vals
        pl.BlockSpec((1, 1, ps, k_max),
                     lambda b_, j, s, tab: (tab[b_, s], j, 0, 0)),      # v_idx
    ]
    operands = [meta, q, pool_k_vals, pool_k_idx, pool_v_vals, pool_v_idx]
    if quantized:
        specs += [
            pl.BlockSpec((1, 1, ps), lambda b_, j, s, tab: (tab[b_, s], j, 0)),
            pl.BlockSpec((1, 1, ps), lambda b_, j, s, tab: (tab[b_, s], j, 0)),
        ]
        operands += [pool_k_scale, pool_v_scale]
    specs += [
        pl.BlockSpec((1, 1, b, dh), lambda b_, j, s, tab: (b_, j, 0, 0)),  # buf_k
        pl.BlockSpec((1, 1, b, dh), lambda b_, j, s, tab: (b_, j, 0, 0)),  # buf_v
        pl.BlockSpec((1, b), lambda b_, j, s, tab: (b_, 0)),            # buf_pos
    ]
    operands += [buf_k, buf_v, buf_pos]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Kv, Pg),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, 1, G, dh),
                               lambda b_, j, s, tab: (b_, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # m
            pltpu.VMEM((G, 1), jnp.float32),   # l
            pltpu.VMEM((G, dh), jnp.float32),  # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, dh), q.dtype),
        interpret=resolve_interpret(interpret),
    )(page_tab, *operands)
