"""Jitted public wrapper for the swan_decode Pallas kernel.

``swan_decode_attention_kernel(q_hat, cache, swan, cfg, pos)`` mirrors
``repro.core.swan_attention.swan_decode_attention`` but runs the fused
Pallas kernel (interpret on CPU, compiled on TPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hybrid_cache import per_seq_pos, sparse_len
from repro.kernels.swan_decode.swan_decode import swan_decode_pallas


@partial(jax.jit, static_argnames=("swan", "cfg", "block_s", "interpret"))
def swan_decode_attention_kernel(q_hat, cache, swan, cfg, pos,
                                 block_s: int = 256, interpret: bool = True):
    if swan.mode != "topk":
        raise NotImplementedError("kernel path covers the paper-faithful "
                                  "'topk' mode; truncate mode is a dense "
                                  "low-rank matmul (plain XLA is optimal)")
    pos = per_seq_pos(pos, q_hat.shape[0])
    sp = sparse_len(swan, pos)
    ks = cache["k"].get("scale")
    vs = cache["v"].get("scale")
    return swan_decode_pallas(
        q_hat, cache["k"]["vals"], cache["k"]["idx"],
        cache["v"]["vals"], cache["v"]["idx"],
        cache["buf_k"], cache["buf_v"], cache["buf_pos"],
        pos, jnp.asarray(sp, jnp.int32),
        k_scale=ks, v_scale=vs,
        block_s=block_s, interpret=interpret)
