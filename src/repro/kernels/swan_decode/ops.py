"""Jitted public wrappers for the swan_decode Pallas kernels.

``swan_decode_attention_kernel(q_hat, cache, swan, cfg, pos)`` mirrors
``repro.core.swan_attention.swan_decode_attention`` but runs the fused
Pallas kernel; ``swan_decode_attention_kernel_paged`` mirrors
``swan_decode_attention_paged`` with the page-table gather executed
inside the kernel (no materialised logical view).

``interpret=None`` resolves from the backend (``repro.kernels.dispatch``):
compiled on TPU, interpreter elsewhere — the old hard-coded
``interpret=True`` silently pinned TPU callers to CPU emulation.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hybrid_cache import per_seq_pos, sparse_len
from repro.kernels.dispatch import resolve_interpret
from repro.kernels.swan_decode.swan_decode import (swan_decode_paged_pallas,
                                                  swan_decode_pallas)


def _require_topk(swan):
    if swan.mode != "topk":
        raise NotImplementedError("kernel path covers the paper-faithful "
                                  "'topk' mode; truncate mode is a dense "
                                  "low-rank matmul (plain XLA is optimal)")


def swan_decode_from_cache(q_hat, cache, swan, pos, block_s: int = 256,
                           interpret: Optional[bool] = None):
    """Un-jitted slab dispatch (for callers already inside jit — the serve
    decode step): unpack the hybrid-cache dict into kernel operands."""
    _require_topk(swan)
    pos = per_seq_pos(pos, q_hat.shape[0])
    sp = sparse_len(swan, pos)
    return swan_decode_pallas(
        q_hat, cache["k"]["vals"], cache["k"]["idx"],
        cache["v"]["vals"], cache["v"]["idx"],
        cache["buf_k"], cache["buf_v"], cache["buf_pos"],
        pos, jnp.asarray(sp, jnp.int32),
        k_scale=cache["k"].get("scale"), v_scale=cache["v"].get("scale"),
        block_s=block_s, interpret=resolve_interpret(interpret))


def swan_decode_paged_from_cache(q_hat, cache, swan, pos, page_tab,
                                 interpret: Optional[bool] = None):
    """Un-jitted paged dispatch: pool sides + page-table prefix straight
    into the scalar-prefetch kernel — ``paged_logical_view`` never runs."""
    _require_topk(swan)
    pos = per_seq_pos(pos, q_hat.shape[0])
    sp = sparse_len(swan, pos)
    pk, pv = cache["pool"]["k"], cache["pool"]["v"]
    return swan_decode_paged_pallas(
        q_hat, pk["vals"], pk["idx"], pv["vals"], pv["idx"],
        cache["buf_k"], cache["buf_v"], cache["buf_pos"],
        pos, jnp.asarray(sp, jnp.int32), page_tab,
        pool_k_scale=pk.get("scale"), pool_v_scale=pv.get("scale"),
        interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("swan", "cfg", "block_s", "interpret"))
def swan_decode_attention_kernel(q_hat, cache, swan, cfg, pos,
                                 block_s: int = 256,
                                 interpret: Optional[bool] = None):
    return swan_decode_from_cache(q_hat, cache, swan, pos, block_s=block_s,
                                  interpret=resolve_interpret(interpret))


@partial(jax.jit, static_argnames=("swan", "cfg", "interpret"))
def swan_decode_attention_kernel_paged(q_hat, cache, swan, cfg, pos,
                                       page_tab,
                                       interpret: Optional[bool] = None):
    return swan_decode_paged_from_cache(q_hat, cache, swan, pos, page_tab,
                                        interpret=resolve_interpret(interpret))
