"""Pure-jnp oracle for the swan_decode kernel: full decompression + exact
softmax over [sparse ‖ buffer] (never used in serving)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def swan_decode_reference(q, k_vals, k_idx, v_vals, v_idx, buf_k, buf_v,
                          buf_pos, pos, sp_len, k_scale=None, v_scale=None):
    B, Kv, G, dh = q.shape
    S = k_vals.shape[2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    sp_len = jnp.broadcast_to(jnp.asarray(sp_len, jnp.int32), (B,))

    def dense(vals, idx, scale):
        v = vals.astype(jnp.float32)
        if scale is not None:
            v = v * scale[..., None]
        out = jnp.zeros((*v.shape[:-1], dh), jnp.float32)
        return jnp.put_along_axis(out, idx.astype(jnp.int32), v, axis=-1,
                                  inplace=False)

    kd = dense(k_vals, k_idx, k_scale)                  # [B,Kv,S,dh]
    vd = dense(v_vals, v_idx, v_scale)
    qf = q.astype(jnp.float32)
    s_sp = jnp.einsum("bjgd,bjtd->bjgt", qf, kd) / math.sqrt(dh)
    sp_ok = jnp.arange(S)[None, :] < sp_len[:, None]            # [B, S]
    s_sp = jnp.where(sp_ok[:, None, None, :], s_sp, -jnp.inf)

    s_b = jnp.einsum("bjgd,bjtd->bjgt", qf,
                     buf_k.astype(jnp.float32)) / math.sqrt(dh)
    b_ok = (buf_pos >= 0) & (buf_pos <= pos[:, None])           # [B, b]
    s_b = jnp.where(b_ok[:, None, None, :], s_b, -jnp.inf)

    s = jnp.concatenate([s_sp, s_b], axis=-1)
    w = jax.nn.softmax(s, axis=-1)
    v_all = jnp.concatenate([vd, buf_v.astype(jnp.float32)], axis=2)
    o = jnp.einsum("bjgt,bjtd->bjgd", w, v_all)
    return o.astype(q.dtype)
