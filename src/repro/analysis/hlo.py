"""Loop-aware HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE,
which undercounts scanned-layer models by ~n_layers× (verified empirically —
see EXPERIMENTS.md §Methodology).  This module parses the post-optimization
HLO text, builds the computation call graph (entry → while bodies / fusions
/ calls) with ``known_trip_count`` multipliers, and accumulates:

  * flops            — dot ops (2·N·K from shapes + contracting dims) plus
                       1 flop/elem for arithmetic elementwise ops,
  * hbm_bytes        — per top-level op: operand result-sizes + own size
                       (fusion internals collapsed — the standard roofline
                       approximation of HBM traffic),
  * collective_bytes — received-bytes per device: result sizes of
                       all-reduce / all-gather / reduce-scatter / all-to-all
                       / collective-permute, broken out per op kind.  Async
                       ``-start``/``-done`` pairs count exactly once: the
                       ``-done`` half is skipped and the ``-start`` half is
                       charged only its RESULT tuple component (the full
                       start tuple carries the operand alias too, which
                       would double the bytes).

``transfer_stats`` is the companion host-boundary census: infeed/outfeed,
host send/recv, device↔host copies (memory space ``S(5)``), and
``MoveToHost``/``MoveToDevice`` annotation custom-calls — the signal the
swanlint compiled-dispatch auditor uses to prove a serve executable never
blocks on the host.  All numbers are per-device (post-SPMD shapes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# Bit widths (not bytes): sub-byte types (s4/u4/f4e2m1fn) pack two
# elements per byte post-0.4.x, so byte totals must round AFTER the
# element product — a [4096,128] s4 tensor is 256 KiB, not 512 KiB.
_DTYPE_BITS = {
    "pred": 8, "s8": 8, "u8": 8, "s16": 16, "u16": 16, "s32": 32,
    "u32": 32, "s64": 64, "u64": 64, "f16": 16, "bf16": 16, "f32": 32,
    "f64": 64, "c64": 64, "c128": 128,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3": 8, "f8e3m4": 8,
    "f8e4m3fnuz": 8, "f8e5m2fnuz": 8, "f8e4m3b11fnuz": 8, "f8e8m0fnu": 8,
    "s4": 4, "u4": 4, "f4e2m1fn": 4,
}
# byte-granular view kept for callers; sub-byte entries round up to 1
_DTYPE_BYTES = {k: max(1, v // 8) for k, v in _DTYPE_BITS.items()}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_EWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
    "power", "floor", "ceil", "round-nearest-afz", "cosine", "sine",
    "expm1", "log1p", "atan2", "remainder",
}


def _shape_info(type_str: str) -> Tuple[int, int]:
    """-> (total bytes, total elements) for a possibly-tuple HLO type.
    Bit-accurate for sub-byte dtypes: the byte count rounds up once per
    shape component, after the element product."""
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BITS:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += (n * _DTYPE_BITS[dt] + 7) // 8
        total_e += n
    return total_b, total_e


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    bytes: int = 0
    elems: int = 0


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_LHS_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TYPE_WORD_RE = re.compile(
    r"^((?:[\w]+\[[\d,]*\](?:\{[\d,:TSE()*]*\})?\s*)+)(.*)$")
_OPCODE_RE = re.compile(r"^([\w\-]+)\((.*)$", re.DOTALL)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def _match_paren(s: str, start: int = 0) -> int:
    """Index just past the paren group opening at s[start] (must be '(')."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def parse_instr_line(line: str) -> Optional[Instr]:
    m = _LHS_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(2), m.group(3)
    if rhs.startswith("("):                  # tuple type (may contain /*i=N*/)
        end = _match_paren(rhs)
        type_str, rest = rhs[:end], rhs[end:].lstrip()
    else:
        mt = _TYPE_WORD_RE.match(rhs)
        if not mt:
            return None
        type_str, rest = mt.group(1), mt.group(2)
    mo = _OPCODE_RE.match(rest)
    if not mo:
        return None
    opcode = mo.group(1)
    tail = "(" + mo.group(2)
    end = _match_paren(tail)
    operands_str, attrs = tail[1:end - 1], tail[end:]
    ops = [o.strip().lstrip("%") for o in _split_top(operands_str)]
    ops = [re.sub(r"^.*\s%?([\w.\-]+)$", r"\1", o) for o in ops if o]
    b, e = _shape_info(type_str)
    return Instr(name, type_str.strip(), opcode, ops, attrs, b, e)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if "=" in line and line.rstrip().endswith("{") and "->" in line:
            mc = _COMP_RE.match(line)
        else:
            mc = _COMP_RE.match(line) if line.rstrip().endswith("{") else None
        if mc:
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = parse_instr_line(line)
        if ins is None:
            continue
        cur.instrs.append(ins)
        cur.by_name[ins.name] = ins
    return comps, entry


def _split_top(s: str) -> List[str]:
    out, depth, buf = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf))
    return out


_TRIP_RE = re.compile(r'known_trip_count\D*?(\d+)')
_CALLED_RE = re.compile(r'(?:body|to_apply|calls|condition)=%?([\w.\-]+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> int:
    """2 × result-elems × contracted-size (batch dims handled naturally)."""
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    contracted = 1
    m = _CONTRACT_RE.search(ins.attrs)
    if lhs is not None and m and m.group(1):
        sm = _SHAPE_RE.search(lhs.type_str)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    contracted *= dims[ci]
    return 2 * ins.elems * contracted


_SLICE_OPS = ("dynamic-slice", "slice")


def _fusion_operand_bytes(callee: Optional["Computation"], index: int,
                          full_bytes: int) -> int:
    """Bytes a fusion actually reads from operand ``index``: if every use
    inside the fused computation goes through a (dynamic-)slice, only the
    sliced regions are read — charging the full stacked array would
    over-count scanned layer stacks ~L×."""
    if callee is None:
        return full_bytes
    param = None
    for ins in callee.instrs:
        if ins.opcode == "parameter" and ins.operands[:1] == [str(index)]:
            param = ins
            break
    if param is None:
        return full_bytes
    consumers = [i for i in callee.instrs if param.name in i.operands]
    if not consumers:
        return 0
    if all(i.opcode in _SLICE_OPS for i in consumers):
        return sum(i.bytes for i in consumers)
    return full_bytes


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = field(default_factory=dict)
    collective_count: int = 0
    host_transfers: int = 0

    def add(self, other: "HloCosts", mult: float) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_count += int(other.collective_count * mult)
        self.host_transfers += int(other.host_transfers * mult)
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


_CC_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')
_MOVE_TARGETS = ("MoveToHost", "MoveToDevice")


def _is_host_transfer(ins: Instr) -> bool:
    """True for the initiating half of any device↔host boundary crossing.
    ``-done`` halves are never passed here (callers skip them), so each
    transfer counts exactly once."""
    op = ins.opcode
    base = op[:-6] if op.endswith("-start") else op
    if base in ("infeed", "outfeed"):
        return True
    if base in ("send", "recv"):
        return "is_host_transfer=true" in ins.attrs
    if base == "copy" and "S(5)" in ins.type_str:
        return True                     # S(5) = host memory space
    if op == "custom-call":
        m = _CC_TARGET_RE.search(ins.attrs)
        return bool(m) and m.group(1) in _MOVE_TARGETS
    return False


def _collective_start_bytes(ins: Instr) -> int:
    """Received bytes for an async ``*-start``: the start op's type is a
    tuple ``(operand..., result, [u32 contexts...])`` whose element 0
    aliases the input — charging the whole tuple double-counts.  Use the
    second component (the result) when the tuple structure is visible."""
    if ins.type_str.startswith("("):
        parts = _split_top(ins.type_str[1:-1].strip())
        if len(parts) >= 2:
            b, _ = _shape_info(parts[1])
            return b
    return ins.bytes


def _comp_costs(comp: Computation, comps: Dict[str, Computation],
                memo: Dict[str, HloCosts], in_fusion: bool = False) -> HloCosts:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = HloCosts()   # cycle guard
    c = HloCosts()
    for ins in comp.instrs:
        op = ins.opcode
        if op.endswith("-done"):
            continue
        base = op.replace("-start", "")
        if _is_host_transfer(ins):
            c.host_transfers += 1
        if base in _COLLECTIVES:
            nbytes = (_collective_start_bytes(ins) if op.endswith("-start")
                      else ins.bytes)
            c.collective_bytes += nbytes
            c.collective_count += 1
            c.per_collective[base] = c.per_collective.get(base, 0.0) + nbytes
            c.hbm_bytes += nbytes
            continue
        if op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            called = _CALLED_RE.findall(ins.attrs)
            for sub in called:
                if sub in comps:
                    c.add(_comp_costs(comps[sub], comps, memo), trip)
            continue
        if op in ("fusion", "call", "conditional", "async-start", "custom-call"):
            callees = [comps[s] for s in _CALLED_RE.findall(ins.attrs)
                       if s in comps]
            for sub in callees:
                sc = _comp_costs(sub, comps, memo, in_fusion=(op == "fusion"))
                # fusion internals: count flops, not bytes
                c.flops += sc.flops
                c.collective_bytes += sc.collective_bytes
                c.collective_count += sc.collective_count
                for k, v in sc.per_collective.items():
                    c.per_collective[k] = c.per_collective.get(k, 0.0) + v
            if not in_fusion:
                callee = callees[0] if op == "fusion" and callees else None
                opb = 0
                for i, o in enumerate(ins.operands):
                    if o not in comp.by_name:
                        continue
                    full = comp.by_name[o].bytes
                    opb += _fusion_operand_bytes(callee, i, full)
                c.hbm_bytes += opb + ins.bytes
            continue
        if op == "dot":
            c.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            c.flops += 2 * ins.elems * 8   # rough; convs are rare here
        elif op in _EWISE_1FLOP:
            c.flops += ins.elems
        elif op in ("reduce", "reduce-window"):
            opb = sum(comp.by_name[o].elems for o in ins.operands
                      if o in comp.by_name)
            c.flops += max(opb, ins.elems)
        if not in_fusion and op not in ("parameter", "constant", "tuple",
                                        "get-tuple-element", "bitcast"):
            if op in ("dynamic-slice", "slice", "gather", "broadcast", "iota"):
                # slicing reads only the sliced region ≈ result size
                c.hbm_bytes += 2 * ins.bytes
            elif op == "dynamic-update-slice":
                upd = (comp.by_name[ins.operands[1]].bytes
                       if len(ins.operands) > 1 and ins.operands[1] in comp.by_name
                       else ins.bytes)
                c.hbm_bytes += 2 * upd     # read region + write region
            elif op == "scatter":
                upd = (comp.by_name[ins.operands[-1]].bytes
                       if ins.operands and ins.operands[-1] in comp.by_name
                       else ins.bytes)
                c.hbm_bytes += 3 * upd     # read idx+updates, rmw region
            else:
                opb = sum(comp.by_name[o].bytes for o in ins.operands
                          if o in comp.by_name)
                c.hbm_bytes += opb + ins.bytes
    memo[comp.name] = c
    return c


def analyze_hlo(text: str) -> HloCosts:
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs)) if comps else None
    if entry is None:
        return HloCosts()
    total = HloCosts()
    total.add(_comp_costs(comps[entry], comps, {}), 1.0)
    return total


@dataclass
class TransferStats:
    """Host-boundary and async-collective census over a whole HLO module
    (every computation, unweighted by trip counts — a single occurrence
    anywhere is already a contract violation for the serve auditor)."""
    infeed: int = 0
    outfeed: int = 0
    host_send: int = 0            # send with is_host_transfer=true
    host_recv: int = 0            # recv with is_host_transfer=true
    host_copy: int = 0            # copy / copy-start into S(5) host space
    move_custom_calls: int = 0    # MoveToHost / MoveToDevice annotations
    collective_starts: int = 0
    collective_dones: int = 0
    unmatched_async: int = 0      # -start with no -done in its computation

    @property
    def host_total(self) -> int:
        return (self.infeed + self.outfeed + self.host_send +
                self.host_recv + self.host_copy + self.move_custom_calls)

    def to_json(self) -> Dict[str, int]:
        return {
            "infeed": self.infeed, "outfeed": self.outfeed,
            "host_send": self.host_send, "host_recv": self.host_recv,
            "host_copy": self.host_copy,
            "move_custom_calls": self.move_custom_calls,
            "collective_starts": self.collective_starts,
            "collective_dones": self.collective_dones,
            "unmatched_async": self.unmatched_async,
            "host_total": self.host_total,
        }


def transfer_stats(text: str) -> TransferStats:
    """Count host transfers and async collective pairs in an HLO module.

    Pairing discipline: the ``-done`` half of any async op is skipped for
    transfer counting (the ``-start`` half is the single countable event),
    and collective ``-start``/``-done`` instructions are matched by name
    within their computation so a dangling start surfaces as
    ``unmatched_async`` instead of silently inflating the start count."""
    comps, _ = parse_module(text)
    ts = TransferStats()
    for comp in comps.values():
        open_starts: set = set()
        for ins in comp.instrs:
            op = ins.opcode
            if op.endswith("-start") and op[:-6] in _COLLECTIVES:
                ts.collective_starts += 1
                open_starts.add(ins.name)
                continue
            if op.endswith("-done"):
                if op[:-5] in _COLLECTIVES:
                    ts.collective_dones += 1
                    if ins.operands:
                        open_starts.discard(ins.operands[0])
                continue              # never recount the -done half
            if not _is_host_transfer(ins):
                continue
            base = op[:-6] if op.endswith("-start") else op
            if base == "infeed":
                ts.infeed += 1
            elif base == "outfeed":
                ts.outfeed += 1
            elif base == "send":
                ts.host_send += 1
            elif base == "recv":
                ts.host_recv += 1
            elif base == "copy":
                ts.host_copy += 1
            else:                     # custom-call MoveToHost/MoveToDevice
                ts.move_custom_calls += 1
        ts.unmatched_async += len(open_starts)
    return ts
