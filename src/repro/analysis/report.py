"""Generate EXPERIMENTS.md sections from dry-run records.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun > EXPERIMENTS.generated.md
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load_records(d: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        out.append(json.load(open(f)))
    return out


def _fmt_bytes(b: float) -> str:
    if b >= 1e12:
        return f"{b / 1e12:.2f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    return f"{b / 1e6:.1f}MB"


def _fmt_s(x: float) -> str:
    return f"{x:.2e}"


def dryrun_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | mesh | swan | status | per-dev args | per-dev temps | coll bytes/dev | collectives |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        sw = "on" if r["swan"] else "—"
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | {sw} | "
                         f"{r['status']}: {reason} | | | | |")
            continue
        m = r["memory"]
        h = r["hlo_cost"]
        per = ", ".join(f"{k}:{_fmt_bytes(v)}"
                        for k, v in sorted(h["per_collective"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {sw} | ok | "
            f"{_fmt_bytes(m['argument_bytes'])} | {_fmt_bytes(m['temp_bytes'])} | "
            f"{_fmt_bytes(h['collective_bytes'])} | {per} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = ["| arch | shape | swan | compute_s | memory_s | collective_s | bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok" or r["multi_pod"]:
            continue
        ro = r["roofline"]
        sw = "on" if r["swan"] else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {sw} | "
            f"{_fmt_s(ro['compute_s'])} | {_fmt_s(ro['memory_s'])} | "
            f"{_fmt_s(ro['collective_s'])} | **{ro['bottleneck']}** | "
            f"{ro['model_flops']:.2e} | {ro['useful_flops_ratio']:.2f} | "
            f"{ro['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def summary_stats(recs: List[Dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    errs = [r for r in recs if r["status"] == "error"]
    doms: Dict[str, int] = {}
    for r in ok:
        if not r["multi_pod"]:
            d = r["roofline"]["bottleneck"]
            doms[d] = doms.get(d, 0) + 1
    out = [f"- compiled OK: **{len(ok)}** cells "
           f"({sum(1 for r in ok if r['multi_pod'])} multi-pod, "
           f"{sum(1 for r in ok if r['swan'])} SWAN variants)",
           f"- skipped by §Arch-applicability: {len(skipped)}",
           f"- errors: {len(errs)}",
           f"- single-pod bottleneck mix: {doms}"]
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load_records(d)
    print("## Dry-run summary\n")
    print(summary_stats(recs))
    print("\n## Dry-run table\n")
    print(dryrun_table(recs))
    print("\n## Roofline table (single-pod 16x16, per-device terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
