"""swanlint Layer 2: compiled-dispatch auditor for the serve hot path.

Layer 1 reads source; this layer reads what XLA actually built.  It
AOT-lowers the ServeEngine's chunk/decode executables over a
(bucket × paged × mesh) matrix via ``ServeEngine.lower_decode`` /
``lower_chunk``, parses the post-optimization HLO through
``repro.analysis.hlo``, and asserts the ROADMAP perf contract:

  (i)   executable-count bounds — power-of-two bucketing keeps the
        compile universe at O(log max_seq): ONE decode executable per
        page bucket (exactly one for slab), one chunk executable per
        (lane, chunk, prefix) bucket, and an identical workload re-run
        compiles NOTHING new.  Counting goes through
        ``ServeEngine.executable_census()`` — decode, prefill, the chunk
        family, both admission inserts and pool-grow — so no family can
        silently escape the bounds;
  (i')  the warmup contract (``warmup_checks``) — after
        ``ServeEngine.warmup()`` the census covers every bucket the
        scheduler can legally request (``repro.runtime.warmup.
        executable_family``), a second warmup compiles nothing, and a
        randomized mixed workload (mixed k, temperatures, prompt lengths
        spanning the chunk/page/prefix buckets) triggers ZERO new XLA
        compiles (``repro.obs.compile_events``);
  (ii)  zero host transfers inside dispatch bodies — no infeed/outfeed,
        no host sends/recvs, no S(5) copies, no MoveToHost annotations
        (the designed host fetch points live OUTSIDE the executables);
  (iii) collective inventory matches the sharding contract — the serve
        path is lane-local by design (shard_map bodies never
        communicate), so the per-collective census must be EMPTY;
  (iv)  Pallas kernel prechecks — grid divisibility and VMEM footprint
        vs the per-core budget for ``swan_decode`` and ``flash_prefill``
        at the engine's shapes.

Each assertion is an ``AuditCheck`` with status pass/fail/skip; the CLI
folds them into the JSON report next to the Layer 1 findings.  The check
helpers (``transfer_check``/``collective_check``/``count_check``) are
pure text/number functions so tests can drive them with synthetic HLO
and synthetic counts — the engine-building matrix is only needed for the
integration smoke.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.analysis.hlo import analyze_hlo, transfer_stats

__all__ = ["AuditCheck", "transfer_check", "collective_check",
           "count_check", "logical_view_check", "kernel_precheck_checks",
           "audit_lowered", "warmup_checks", "run_audit"]


@dataclass
class AuditCheck:
    check: str                  # e.g. "host-transfers/slab/decode"
    status: str                 # "pass" | "fail" | "skip"
    detail: str = ""

    def to_json(self) -> Dict[str, str]:
        return {"check": self.check, "status": self.status,
                "detail": self.detail}


# ---------------------------------------------------------------------------
# Pure check helpers (unit-testable without building an engine)
# ---------------------------------------------------------------------------

def transfer_check(hlo_text: str, label: str) -> AuditCheck:
    """(ii): the executable must not cross the host boundary, and every
    async start must pair with a done."""
    ts = transfer_stats(hlo_text)
    problems = []
    if ts.host_total:
        problems.append(f"{ts.host_total} host transfer(s): "
                        f"{ts.to_json()}")
    if ts.unmatched_async:
        problems.append(f"{ts.unmatched_async} unmatched async "
                        "collective start(s)")
    if problems:
        return AuditCheck(f"host-transfers/{label}", "fail",
                          "; ".join(problems))
    return AuditCheck(f"host-transfers/{label}", "pass",
                      "no host boundary crossings")


def collective_check(hlo_text: str, label: str,
                     allowed: tuple = ()) -> AuditCheck:
    """(iii): collective inventory vs the declared sharding contract
    (empty for the lane-local serve path)."""
    costs = analyze_hlo(hlo_text)
    extra = {k: v for k, v in costs.per_collective.items()
             if k not in allowed}
    if extra:
        return AuditCheck(
            f"collectives/{label}", "fail",
            f"undeclared collectives on the serve path: {extra}")
    return AuditCheck(f"collectives/{label}", "pass",
                      f"inventory matches contract (allowed={list(allowed)})")


def count_check(label: str, observed: int, bound: int,
                what: str = "executables") -> AuditCheck:
    """(i): observed compiled-executable count within its O(log) bound."""
    if observed < 0:
        return AuditCheck(f"exec-count/{label}", "skip",
                          "cache size not exposed by this jax version")
    if observed > bound:
        return AuditCheck(f"exec-count/{label}", "fail",
                          f"{observed} {what} > bound {bound}")
    return AuditCheck(f"exec-count/{label}", "pass",
                      f"{observed} {what} <= bound {bound}")


def _log2_buckets(n: int) -> int:
    """Number of power-of-two buckets in [1, n]."""
    return max(1, int(math.log2(max(1, n))) + 1)


_GATHER_RE = re.compile(
    r"=\s*[a-z0-9]+\[([0-9,]*)\][^=]*\bgather\(")


def logical_view_check(hlo_text: str, label: str, view_elems: int,
                       expect_materialized: bool = False) -> AuditCheck:
    """Paged decode HLO inspection: the Pallas paged kernel gathers pool
    pages INSIDE the kernel (page-table scalar prefetch -> VMEM tiles), so
    its executable must contain no gather materialising the
    ``paged_logical_view`` — i.e. no gather whose result holds at least
    ``view_elems`` elements (= B x Kv x bucket*page_size x k_max, the
    view's vals leaf).  ``expect_materialized=True`` inverts the check for
    the pure-JAX reference executable, proving the detector actually sees
    the logical-view gather it is meant to rule out."""
    big = []
    for m in _GATHER_RE.finditer(hlo_text):
        dims = m.group(1)
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        if elems >= view_elems:
            big.append((dims, elems))
    name = f"logical-view/{label}"
    if expect_materialized:
        if big:
            return AuditCheck(name, "pass",
                              f"reference path materialises the view as "
                              f"expected: gather result [{big[0][0]}]")
        return AuditCheck(name, "fail",
                          f"detector found no gather >= {view_elems} "
                          "elements in the reference executable — "
                          "threshold or HLO idiom drifted")
    if big:
        return AuditCheck(name, "fail",
                          f"{len(big)} materialised logical-view gather(s) "
                          f">= {view_elems} elements: "
                          f"{[d for d, _ in big[:3]]}")
    return AuditCheck(name, "pass",
                      f"no gather >= {view_elems} elements — pool pages "
                      "stream through the kernel's VMEM tiles")


def kernel_precheck_checks(cfg, swan, max_seq: int,
                           page_size: Optional[int] = None,
                           chunk_q: Optional[int] = None) -> List[AuditCheck]:
    """(iv): static Pallas grid/VMEM validation at the engine's shapes.
    ``page_size`` adds the paged-tile grid (sequence blocks = page-sized
    pool tiles gathered via scalar prefetch); ``chunk_q`` adds the
    bulk-chunk prefill stats kernel at that query-row count."""
    from repro.kernels.flash_prefill import flash_prefill as fp
    from repro.kernels.flash_prefill import swan_chunk as sc
    from repro.kernels.swan_decode import swan_decode as sd

    def fold(name: str, r: dict) -> AuditCheck:
        status = "fail" if r["errors"] else "pass"
        detail = "; ".join(r["errors"] + r["warnings"]) or \
            f"vmem {r['vmem_bytes']} B"
        return AuditCheck(f"pallas-precheck/{name}", status, detail)

    out: List[AuditCheck] = []
    if swan is not None:
        quant = getattr(swan, "quantize", False)
        G = cfg.n_heads // cfg.n_kv_heads
        out.append(fold("swan_decode", sd.precheck(
            B=1, Kv=cfg.n_kv_heads, G=G, dh=cfg.d_head, S=max(max_seq, 1),
            k_max=swan.k_max, b=swan.buffer, quantized=quant)))
        if page_size is not None:
            # paged-tile grid: sequence blocks are pool pages, so the
            # block is the page and S spans the per-seq page reservation
            n_pg = max(max_seq // page_size, 1)
            out.append(fold("swan_decode@paged", sd.precheck(
                B=1, Kv=cfg.n_kv_heads, G=G, dh=cfg.d_head,
                S=n_pg * page_size, k_max=swan.k_max, b=swan.buffer,
                block_s=page_size, quantized=quant)))
        if chunk_q is not None:
            out.append(fold("swan_chunk_stats", sc.precheck(
                B=1, Kv=cfg.n_kv_heads, Q=chunk_q, dh=cfg.d_head,
                S=max(max_seq, 1), k_max=swan.k_max, quantized=quant)))
            if page_size is not None:
                n_pg = max(max_seq // page_size, 1)
                out.append(fold("swan_chunk_stats@paged", sc.precheck(
                    B=1, Kv=cfg.n_kv_heads, Q=chunk_q, dh=cfg.d_head,
                    S=n_pg * page_size, k_max=swan.k_max,
                    block_s=page_size, quantized=quant)))
    else:
        out.append(AuditCheck("pallas-precheck/swan_decode", "skip",
                              "no SWAN config on this engine"))
    r = fp.precheck(B=1, H=cfg.n_heads, Kv=cfg.n_kv_heads, Sq=max_seq,
                    Sk=max_seq, dh=cfg.d_head)
    status = "fail" if r["errors"] else "pass"
    detail = "; ".join(r["errors"] + r["warnings"]) or \
        f"vmem {r['vmem_bytes']} B"
    out.append(AuditCheck("pallas-precheck/flash_prefill", status, detail))
    return out


# ---------------------------------------------------------------------------
# Engine-driven audit
# ---------------------------------------------------------------------------

def audit_lowered(eng, label: str,
                  page_buckets: tuple = (None,)) -> List[AuditCheck]:
    """Checks (ii)+(iii) over the engine's AOT-lowered decode and chunk
    executables, one decode per requested page bucket."""
    out: List[AuditCheck] = []
    for pb in page_buckets:
        tag = f"{label}/decode" + (f"@pg{pb}" if pb is not None else "")
        try:
            txt = eng.lower_decode(page_bucket=pb).compile().as_text()
        except Exception as e:                         # pragma: no cover
            out.append(AuditCheck(f"lower/{tag}", "fail", repr(e)))
            continue
        out.append(transfer_check(txt, tag))
        out.append(collective_check(txt, tag))
    tag = f"{label}/chunk"
    try:
        txt = eng.lower_chunk().compile().as_text()
    except Exception as e:                             # pragma: no cover
        out.append(AuditCheck(f"lower/{tag}", "fail", repr(e)))
        return out
    out.append(transfer_check(txt, tag))
    out.append(collective_check(txt, tag))
    return out


def _drive(eng, prompts, max_new: int = 3) -> None:
    from repro.runtime.serve_engine import Request
    for u, p in enumerate(prompts):
        eng.submit(Request(uid=f"a{u}", tokens=p, max_new_tokens=max_new))
    while not eng.done:
        eng.step()


def _exec_count_checks(make_engine, label: str, prompts,
                       paged: bool) -> List[AuditCheck]:
    """(i): drive a mixed-length workload, bound the compile universe
    family by family via ``executable_census()`` (decode, prefill, the
    chunk family, BOTH admission inserts and pool-grow — the families the
    old decode/prefill-only counting missed), then re-run the identical
    workload and require zero new compiles anywhere."""
    out: List[AuditCheck] = []
    eng = make_engine()
    _drive(eng, prompts)
    try:
        census = eng.executable_census()
    except RuntimeError as e:
        return [AuditCheck(f"exec-count/{label}", "skip", str(e))]
    if paged:
        dec_bound = _log2_buckets(eng.pool.pages_per_seq)
    else:
        dec_bound = 1
    # chunk executables: one per (lane-width, chunk-len, prefix/table
    # bucket) triple, each axis O(log) by power-of-two bucketing
    chunk_bound = (_log2_buckets(eng.n_slots)
                   * _log2_buckets(eng.prefill_chunk or 1)
                   * _log2_buckets(eng.pool.pages_per_seq if paged
                                   else eng.max_seq))
    out.append(count_check(f"{label}/decode", census["decode"], dec_bound,
                           "decode executables"))
    out.append(count_check(f"{label}/prefill+chunk",
                           census["prefill"] + census["chunk_total"],
                           1 + chunk_bound, "prefill executables"))
    # admission inserts compile once per monolithic prompt pad bucket
    # (zero on the chunked path); pool-grow once per growth delta
    out.append(count_check(f"{label}/insert",
                           census["insert"] + census["insert_paged"],
                           _log2_buckets(eng.max_seq), "insert executables"))
    out.append(count_check(f"{label}/pool-grow", census["pool_grow_total"],
                           _log2_buckets(eng.max_seq), "grow executables"))
    _drive(eng, prompts)                       # identical workload again
    census2 = eng.executable_census()
    if census2 != census:
        out.append(AuditCheck(
            f"exec-count/{label}/steady-state", "fail",
            f"identical workload recompiled: {census} -> {census2}"))
    else:
        out.append(AuditCheck(f"exec-count/{label}/steady-state", "pass",
                              "no new executables on identical re-run "
                              "(full census stable)"))
    return out


def _mixed_workload(vocab: int, max_prompt_len: int, seed: int = 0):
    """Randomized mixed serve workload for the post-warmup zero-compile
    gate: prompt lengths spanning the chunk/page/prefix buckets, mixed
    per-request SWAN k, greedy and temperature lanes.  Token ids come from
    seeded numpy (NOT jnp slicing — building the workload itself must not
    compile anything)."""
    import numpy as np
    from repro.runtime.serve_engine import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for u in range(8):
        plen = int(rng.randint(1, max_prompt_len + 1))
        reqs.append(Request(
            uid=f"w{u}",
            tokens=rng.randint(0, vocab, size=plen).tolist(),
            max_new_tokens=int(rng.randint(2, 5)),
            temperature=float(rng.choice([0.0, 0.0, 0.7, 1.3])),
            seed=int(rng.randint(0, 2 ** 16)),
            k=[None, 4, 8][int(rng.randint(0, 3))]))
    return reqs


def warmup_checks(make_engine, label: str, vocab: int,
                  max_prompt_len: int = 16) -> List[AuditCheck]:
    """The warmup contract, machine-checked:

    (a) coverage — after ``warmup()`` the executable census meets the
        static family enumeration bucket by bucket (any legally
        requestable bucket absent from the warmed family fails);
    (b) idempotency — a second ``warmup()`` compiles nothing;
    (c) zero steady-state compiles — a randomized mixed workload (mixed
        k, temperatures, prompt lengths spanning the buckets) triggers
        zero XLA compiles and leaves the census bit-identical.
    """
    from repro.obs import compile_events
    out: List[AuditCheck] = []
    eng = make_engine()
    try:
        report = eng.warmup(max_prompt_len=max_prompt_len)
    except Exception as e:
        return [AuditCheck(f"warmup/{label}", "fail", repr(e))]
    census, exp = report["census"], report["expected"]
    missing = [f"{fam}: {census[fam]} < {exp[fam]}"
               for fam in ("decode", "prefill", "insert", "insert_paged")
               if census[fam] < exp[fam]]
    missing += [f"chunk[{key}]: {census['chunk'].get(key, 0)} < {n}"
                for key, n in exp["chunk"].items()
                if census["chunk"].get(key, 0) < n]
    if missing:
        out.append(AuditCheck(
            f"warmup/{label}/coverage", "fail",
            "legally-requestable buckets absent from the warmed family: "
            + "; ".join(missing)))
    else:
        out.append(AuditCheck(
            f"warmup/{label}/coverage", "pass",
            f"census covers the enumerated family "
            f"({census['total']} executables, "
            f"{report['compiles']} compiles in "
            f"{report['warmup_ms']:.0f} ms)"))
    rep2 = eng.warmup(max_prompt_len=max_prompt_len)
    if rep2["compiles"]:
        out.append(AuditCheck(
            f"warmup/{label}/idempotent", "fail",
            f"second warmup compiled {rep2['compiles']} executable(s): "
            f"{[r for r in rep2['items'] if r['compiles']][:3]}"))
    else:
        out.append(AuditCheck(f"warmup/{label}/idempotent", "pass",
                              "second warmup compiled nothing"))
    reqs = _mixed_workload(vocab, max_prompt_len)
    c0 = compile_events.total()
    for r in reqs:
        eng.submit(r)
    while not eng.done:
        eng.step()
    dc = compile_events.total() - c0
    census2 = eng.executable_census()
    if dc or census2 != census:
        out.append(AuditCheck(
            f"warmup/{label}/zero-compile", "fail",
            f"post-warmup mixed workload compiled {dc} executable(s); "
            f"census {'stable' if census2 == census else 'DRIFTED'}"))
    else:
        out.append(AuditCheck(
            f"warmup/{label}/zero-compile", "pass",
            f"{len(reqs)}-request mixed workload: 0 compiles, census "
            "stable"))
    return out


def run_audit(smoke: bool = True) -> List[AuditCheck]:
    """Build the (bucket × paged × mesh) engine matrix on the smoke config
    and run every check.  Matrix: slab dp=1, paged dp=1, and paged dp=2
    when >= 2 devices are visible (CI forces 2 host devices)."""
    import jax
    import numpy as np
    from repro.configs import SwanConfig, get_smoke_config
    from repro.launch.io import make_batch
    from repro.models import get_model
    from repro.runtime.serve_engine import ServeEngine
    from repro.runtime.serve_loop import calibrate_swan

    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
    swan = SwanConfig(k_max=cfg.d_head, buffer=4, mode="topk")
    max_seq = 64

    def prompts():
        rng = np.random.RandomState(0)
        return [rng.randint(0, cfg.vocab_size, size=n).tolist()
                for n in (5, 11, 19)]

    page_size = 16
    checks: List[AuditCheck] = kernel_precheck_checks(
        cfg, swan, max_seq, page_size=page_size,
        chunk_q=8 * (cfg.n_heads // cfg.n_kv_heads))

    # xla = pure-JAX reference read path; pallas = kernel-backed decode
    # and chunk attention reads (interpret mode on CPU — the HLO contract
    # checks cover the same executables production would dispatch)
    variants = [("slab", dict(paged=False)),
                ("paged", dict(paged=True, page_size=page_size)),
                ("slab-pallas", dict(paged=False, use_pallas=True)),
                ("paged-pallas", dict(paged=True, page_size=page_size,
                                      use_pallas=True))]
    for label, kw in variants:
        def make_engine(kw=kw):
            return ServeEngine(cfg, params, swan=swan, projections=pj,
                               n_slots=2, max_seq=max_seq, prefill_chunk=8,
                               prefill_slots=2, **kw)
        if not kw.get("use_pallas"):
            # executable-count bounds are trace-shape properties, identical
            # across read-path implementations — drive them once per layout
            checks += _exec_count_checks(make_engine, label, prompts(),
                                         paged=kw.get("paged", False))
            # warmup contract: full-family coverage, idempotency, zero
            # compiles under a randomized mixed workload (also once per
            # layout — the family enumeration is read-path independent)
            checks += warmup_checks(make_engine, label, cfg.vocab_size)
        eng = make_engine()
        checks += audit_lowered(eng, label)
        if kw.get("paged"):
            # the materialised-logical-view detector: the kernel path must
            # gather pool pages in VMEM only; the reference path must trip
            # the detector (proving the threshold still matches the HLO)
            pb = 2
            view = eng.n_slots * cfg.n_kv_heads * pb * page_size * swan.k_max
            txt = eng.lower_decode(page_bucket=pb).compile().as_text()
            checks.append(logical_view_check(
                txt, f"{label}/decode@pg{pb}", view,
                expect_materialized=not kw.get("use_pallas")))

    if jax.device_count() >= 2:
        mesh = jax.make_mesh((2,), ("data",))
        eng = ServeEngine(cfg, params, swan=swan, projections=pj,
                          n_slots=2, max_seq=max_seq, prefill_chunk=8,
                          prefill_slots=2, paged=True, page_size=16,
                          mesh=mesh)
        checks += audit_lowered(eng, "paged-dp2")
    else:
        checks.append(AuditCheck("lower/paged-dp2", "skip",
                                 f"{jax.device_count()} device(s) visible"))
    return checks
