"""swanlint — repo-invariant static analysis for the SWAN serve stack.

Two layers:

* **Layer 1 (``repro.analysis.lint.rules``)** — stdlib-``ast`` rules
  that machine-check the ROADMAP standing constraints at review time:
  JAX-floor compat (SWAN101), no host syncs on the serve hot path
  (SWAN102), power-of-two shape bucketing in dispatch builders
  (SWAN103), sharding-spec completeness for serve-state leaves
  (SWAN104), and MetricsRegistry-only observability (SWAN105).
  Dependency-free: no jax import, runs anywhere.
* **Layer 2 (``repro.analysis.lint.audit``)** — a compiled-artifact
  auditor that lowers the engine's chunk/decode executables for a
  (bucket × paged × mesh) matrix, parses post-optimization HLO through
  ``repro.analysis.hlo``, and asserts the perf contract: bounded
  executable counts (one per step shape), zero host transfers inside
  dispatch bodies, an empty collective inventory (the serve path is
  lane-local by contract), and Pallas grid/VMEM prechecks for the
  ``swan_decode`` / ``flash_prefill`` kernels.

CLI: ``python -m repro.analysis.lint [--check] [--audit-smoke] ...`` —
see ``docs/static_analysis.md`` for the rule catalogue, suppression
policy and baseline workflow.  The committed clean baseline lives at
``bench_out/LINT_BASELINE.json``; ``--check`` fails only on findings
NOT in the baseline, so diffs surface new violations exactly.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional

from repro.analysis.lint.rules import (Finding, RULES, lint_paths,
                                       lint_source)

__all__ = ["Finding", "RULES", "lint_source", "lint_paths",
           "collect_files", "run_lint", "make_report", "load_baseline",
           "new_findings", "DEFAULT_BASELINE"]

DEFAULT_BASELINE = os.path.join("bench_out", "LINT_BASELINE.json")

# what Layer 1 walks by default: library code + the benchmark/example
# drivers (tests are exempt — they intentionally seed violations)
DEFAULT_SCAN_DIRS = ("src/repro", "benchmarks", "examples")


def collect_files(root: str,
                  dirs: Iterable[str] = DEFAULT_SCAN_DIRS) -> List[str]:
    out: List[str] = []
    for d in dirs:
        base = os.path.join(root, d)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    out.append(os.path.relpath(os.path.join(dirpath, fn),
                                               root))
    return sorted(out)


def run_lint(root: str,
             dirs: Iterable[str] = DEFAULT_SCAN_DIRS) -> List[Finding]:
    return lint_paths(root, collect_files(root, dirs))


def make_report(findings: List[Finding],
                audit_checks: Optional[List] = None,
                baseline: Optional[Dict] = None) -> Dict:
    """JSON-serializable report: full finding list, active/suppressed
    split, new-vs-baseline diff, optional Layer 2 results."""
    new = new_findings(findings, baseline)
    rep: Dict = {
        "tool": "swanlint",
        "version": 1,
        "rules": RULES,
        "findings": [f.to_json() for f in findings],
        "counts": {
            "total": len(findings),
            "active": sum(not f.suppressed for f in findings),
            "suppressed": sum(f.suppressed for f in findings),
            "new": len(new),
        },
        "new_findings": [f.to_json() for f in new],
    }
    if audit_checks is not None:
        rep["audit"] = [c.to_json() for c in audit_checks]
        rep["counts"]["audit_failures"] = sum(
            c.status == "fail" for c in audit_checks)
    return rep


def load_baseline(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def new_findings(findings: List[Finding],
                 baseline: Optional[Dict]) -> List[Finding]:
    """Active findings whose fingerprint is not in the baseline.
    Fingerprints are line-number-free (rule|path|normalized snippet), so
    unrelated edits above a known finding don't resurface it."""
    active = [f for f in findings if not f.suppressed]
    if not baseline:
        return active
    known = {f.get("fingerprint") for f in baseline.get("findings", [])}
    return [f for f in active if f.fingerprint not in known]
