"""swanlint Layer 1 — stdlib-``ast`` rules over the repo's standing
constraints (ROADMAP §Standing constraints).

Five rules, each with a stable ID so findings can be suppressed inline::

    # swanlint: disable=SWAN102 -- host fetch point, tokens must cross here

A suppression REQUIRES justification text after the rule list (``--``,
``:`` or parentheses); a bare ``disable=`` is itself a finding
(SWAN100).  A suppression on its own comment line covers the next line.

Rules
-----
SWAN101  JAX-floor: direct imports/uses of post-0.4.35 APIs
         (``jax.shard_map``, ``jax.sharding.AxisType``, …) anywhere but
         the two compat shims ``repro.launch.mesh`` /
         ``repro.sharding.api``.  The floor is a CI pin; an unguarded
         use breaks the 0.4.35 leg.
SWAN102  Host sync on the serve hot path: ``.item()``,
         ``block_until_ready``, ``jax.device_get``, and
         ``float()/int()/bool()/np.asarray()`` applied to values
         tainted by a jitted-dispatch result, in any function reachable
         from an engine's ``step()``/``run()`` loop.  Known host fetch
         points (``_resolve_tokens``, ``_lane_tokens``, ``_sample``) are
         allowlisted — those are where tokens are SUPPOSED to cross.
SWAN103  Shape bucketing: non-power-of-two literal dims in array
         constructors inside dispatch-builder functions under
         ``runtime/`` / ``models/`` — a stray literal like 48 mints a
         new executable per occurrence instead of riding a bucket.
SWAN104  Spec completeness (cross-module): every serve-state leaf key
         constructed by the cache/state initialisers must appear in
         ``repro.sharding.serve_specs.KNOWN_LEAF_NAMES`` — the static
         twin of the ``unspecced_serve_leaves`` runtime check (an
         unknown leaf ships replicated and every shard writes it).
SWAN105  Observability: module-level metric containers (dicts named
         ``*_metrics``/``*_counters``/…) outside ``repro.obs`` —
         counters/gauges must go through the ``MetricsRegistry``
         getters (``repro.obs.metrics.REGISTRY_GETTERS``) so they land
         in the exposition and the schema-drift guard.

Everything here is pure ``ast`` + ``re`` — no jax import, so Layer 1
runs anywhere (pre-commit, CI, a box without the accelerator stack).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "SWAN100": "malformed swanlint suppression (unknown rule id or "
               "missing justification)",
    "SWAN101": "post-0.4.35 JAX API used outside the compat shims",
    "SWAN102": "host sync on the serve hot path",
    "SWAN103": "non-power-of-two literal shape in a dispatch builder",
    "SWAN104": "serve-state leaf without a sharding-spec rule",
    "SWAN105": "ad-hoc metrics container outside MetricsRegistry",
}

# modules allowed to touch post-floor JAX APIs (the version shims)
FLOOR_SHIM_MODULES = ("repro/launch/mesh.py", "repro/sharding/api.py")

# post-0.4.35 API surface (dotted names); the floor itself
# (jax.make_mesh) is fine
POST_FLOOR_APIS = (
    "jax.shard_map",
    "jax.sharding.AxisType",
    "jax.sharding.use_mesh",
    "jax.sharding.reshard",
    "jax.sharding.auto_axes",
    "jax.sharding.explicit_axes",
    "jax.experimental.shard_map",
)

# known host fetch points: the functions whose JOB is to move sampled
# tokens/logits across the device boundary (engine docstrings state the
# contract; everything else reachable from step() must stay device-side).
# _resolve_tokens is the async-fetch sync point: _start_fetch issues the
# copy, _resolve_tokens is where the host finally blocks on it.
HOST_FETCH_ALLOWLIST = ("_resolve_tokens", "_lane_tokens", "_sample")

# sync primitives flagged unconditionally on the hot path
_SYNC_ATTRS = ("item", "block_until_ready")
_SYNC_DOTTED = ("jax.device_get", "jax.block_until_ready")
# conversions flagged only when applied to a dispatch-tainted value
_CONV_NAMES = ("float", "int", "bool")
_CONV_DOTTED = ("np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "np.ascontiguousarray", "numpy.ascontiguousarray")

# array constructors whose literal dims SWAN103 inspects
_CTOR_DOTTED_TAILS = ("zeros", "ones", "full", "empty", "broadcast_to")
_DISPATCH_FN_RE = re.compile(
    r"decode|prefill|chunk|dispatch|serve|insert|step")

# modules whose state initialisers feed the serve engine's pytrees
# (SWAN104 scope; encdec state is lockstep-session only, never sharded)
SPEC_STATE_MODULES = (
    "core/hybrid_cache.py", "core/paged_cache.py", "models/attention.py",
    "models/mamba.py", "models/rwkv.py", "models/rwkv_model.py",
    "models/transformer.py", "models/jamba.py",
)
_STATE_INIT_RE = re.compile(r"^(_?side|init_\w*(cache|state|pool))$")

_METRIC_NAME_RE = re.compile(r"(metric|counter|gauge|histogram)s?(_|$)",
                             re.IGNORECASE)


@dataclass
class Finding:
    rule: str
    path: str            # repo-relative path
    line: int
    col: int
    message: str
    snippet: str = ""
    suppressed: bool = False
    justification: str = ""

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used for baseline diffs: a finding
        moves with its source line, not with unrelated edits above it."""
        return f"{self.rule}|{self.path}|{' '.join(self.snippet.split())}"

    def to_json(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet, "suppressed": self.suppressed,
                "justification": self.justification,
                "fingerprint": self.fingerprint}


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*swanlint:\s*disable=([A-Z0-9, ]+?)(?:\s*(?:--|:|\())(.*)$")
_SUPPRESS_BARE_RE = re.compile(r"#\s*swanlint:\s*disable=?(.*)$")


def _parse_suppressions(lines: Sequence[str], path: str
                        ) -> Tuple[List[Tuple[int, Set[str], str]],
                                   List[Finding]]:
    """-> ([(line, rule ids, justification)], malformed findings)."""
    out: List[Tuple[int, Set[str], str]] = []
    bad: List[Finding] = []
    for i, raw in enumerate(lines, 1):
        if "swanlint" not in raw:
            continue
        m = _SUPPRESS_RE.search(raw)
        if not m:
            if _SUPPRESS_BARE_RE.search(raw):
                bad.append(Finding(
                    "SWAN100", path, i, 0,
                    "suppression needs 'disable=RULE -- justification'",
                    snippet=raw.strip()))
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        just = m.group(2).strip().rstrip(")").strip()
        unknown = rules - set(RULES)
        if unknown:
            bad.append(Finding(
                "SWAN100", path, i, 0,
                f"unknown rule id(s) in suppression: {sorted(unknown)}",
                snippet=raw.strip()))
            rules -= unknown
        if not just:
            bad.append(Finding(
                "SWAN100", path, i, 0,
                "suppression without justification text "
                "(say WHY the finding is safe)", snippet=raw.strip()))
            continue                       # unjustified => does not suppress
        if rules:
            out.append((i, rules, just))
    return out, bad


def _is_comment_line(line: str) -> bool:
    s = line.strip()
    return not s or s.startswith("#")


def suppression_map(text: str, tree: Optional[ast.Module], path: str
                    ) -> Tuple[Dict[int, Tuple[Set[str], str]],
                               List[Finding]]:
    """Resolve suppression comments to the line ranges they cover.

    A suppression covers the whole LOGICAL STATEMENT it annotates: an
    inline trailing comment covers its own (possibly multi-line)
    statement; a standalone comment (or block of comment lines) covers
    the next statement.  Statement extents come from the AST, so a
    suppression above a multi-line dict literal covers every line of
    it."""
    lines = text.splitlines()
    entries, bad = _parse_suppressions(lines, path)
    spans: List[Tuple[int, int]] = []
    if tree is not None:
        for node in ast.walk(tree):
            if isinstance(node, ast.stmt) and hasattr(node, "end_lineno"):
                spans.append((node.lineno, node.end_lineno or node.lineno))
    out: Dict[int, Tuple[Set[str], str]] = {}

    def cover(ln: int, rules: Set[str], just: str) -> None:
        old = out.get(ln)
        out[ln] = ((old[0] | rules, old[1] or just) if old
                   else (rules, just))

    for lineno, rules, just in entries:
        target = lineno
        if lineno - 1 < len(lines) and _is_comment_line(lines[lineno - 1]):
            target = lineno + 1
            while target <= len(lines) \
                    and _is_comment_line(lines[target - 1]):
                target += 1
        # innermost statement containing the target line
        hits = [(l0, l1) for l0, l1 in spans if l0 <= target <= l1]
        if hits:
            l0, l1 = max(hits, key=lambda s: s[0])
            for ln in range(l0, l1 + 1):
                cover(ln, rules, just)
        else:
            cover(target, rules, just)
        cover(lineno, rules, just)
    return out, bad


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _names_in(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            d = _dotted(n)
            if d is not None and d.startswith("self."):
                out.add(d)
    return out


def _snippet(lines: Sequence[str], lineno: int) -> str:
    if 1 <= lineno <= len(lines):
        return lines[lineno - 1].strip()
    return ""


# ---------------------------------------------------------------------------
# SWAN101 — JAX floor
# ---------------------------------------------------------------------------

def _rule_floor(tree: ast.AST, rel: str, lines) -> List[Finding]:
    if rel.replace("\\", "/").endswith(FLOOR_SHIM_MODULES):
        return []
    out: List[Finding] = []

    def hit(lineno, col, api):
        out.append(Finding(
            "SWAN101", rel, lineno, col,
            f"{api} is newer than the JAX 0.4.35 floor — go through "
            "repro.launch.mesh / repro.sharding.api.shard_map_compat",
            snippet=_snippet(lines, lineno)))

    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            for alias in node.names:
                full = f"{mod}.{alias.name}"
                if full in POST_FLOOR_APIS or mod in POST_FLOOR_APIS:
                    hit(node.lineno, node.col_offset, full)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in POST_FLOOR_APIS:
                    hit(node.lineno, node.col_offset, alias.name)
        elif isinstance(node, ast.Attribute):
            d = _dotted(node)
            if d in POST_FLOOR_APIS:
                hit(node.lineno, node.col_offset, d)
    return out


# ---------------------------------------------------------------------------
# SWAN102 — host sync on the serve hot path
# ---------------------------------------------------------------------------

@dataclass
class _FnInfo:
    node: ast.AST                       # FunctionDef | AsyncFunctionDef
    qual: str                           # "Class.method" or "function"
    name: str
    cls: Optional[str]
    calls: Set[str] = field(default_factory=set)        # bare callee names
    tainted_params: Set[str] = field(default_factory=set)


def _function_index(tree: ast.Module) -> List[_FnInfo]:
    fns: List[_FnInfo] = []

    def visit(node, cls):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{cls}.{child.name}" if cls else child.name
                fns.append(_FnInfo(child, qual, child.name, cls))
                visit(child, cls)

    visit(tree, None)
    return fns


def _dispatch_names(tree: ast.Module) -> Set[str]:
    """Attr/local names bound to jitted dispatch callables: RHS is a call
    to ``jax.jit`` or ``shard_map_compat`` (possibly nested)."""
    out: Set[str] = set()

    def jit_call(node) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                d = _dotted(n.func)
                if d in ("jax.jit", "jit") or (
                        d is not None and d.endswith("shard_map_compat")):
                    return True
        return False

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and jit_call(node.value):
            for tgt in node.targets:
                for t in (tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]):
                    if isinstance(t, ast.Attribute):
                        out.add(t.attr)
                    elif isinstance(t, ast.Name):
                        out.add(t.id)
    return out


def _call_name(call: ast.Call) -> Optional[str]:
    """Bare callee name: ``self.f(...)`` / ``f(...)`` -> 'f'."""
    f = call.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
            and f.value.id in ("self", "cls"):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def _rule_host_sync(tree: ast.Module, rel: str, lines) -> List[Finding]:
    rel_n = rel.replace("\\", "/")
    if "/runtime/" not in rel_n and not rel_n.startswith("runtime/"):
        return []
    fns = _function_index(tree)
    by_name: Dict[str, _FnInfo] = {}
    for f in fns:
        by_name.setdefault(f.name, f)
    dispatch = _dispatch_names(tree)
    if not dispatch:
        return []

    for f in fns:
        for n in ast.walk(f.node):
            if isinstance(n, ast.Call):
                cn = _call_name(n)
                if cn:
                    f.calls.add(cn)

    # hot set: BFS over bare-name calls from step()/run()
    roots = [f.name for f in fns if f.name in ("step", "run")]
    if not roots:
        return []
    hot: Set[str] = set()
    frontier = list(roots)
    while frontier:
        name = frontier.pop()
        if name in hot or name not in by_name:
            continue
        hot.add(name)
        frontier.extend(by_name[name].calls)

    # functions that RETURN a dispatch result propagate taint to callers
    returns_tainted: Set[str] = set()
    for f in fns:
        for n in ast.walk(f.node):
            if isinstance(n, ast.Return) and n.value is not None:
                for c in ast.walk(n.value):
                    if isinstance(c, ast.Call):
                        cn = _call_name(c)
                        if cn in dispatch:
                            returns_tainted.add(f.name)

    tainted_attrs: Set[str] = set()      # "self.x" assigned from dispatch

    def analyze(f: _FnInfo, emit: bool) -> List[Finding]:
        """One pass over ``f``: track tainted locals, optionally emit
        findings, and record tainted args at call sites."""
        tainted: Set[str] = set(f.tainted_params)
        out: List[Finding] = []

        def is_tainted(expr: ast.AST) -> bool:
            for n in ast.walk(expr):
                if isinstance(n, ast.Call):
                    cn = _call_name(n)
                    if cn in dispatch or cn in returns_tainted:
                        return True
            names = _names_in(expr)
            return bool(names & tainted or names & tainted_attrs)

        for node in ast.walk(f.node):
            if isinstance(node, ast.Assign) and is_tainted(node.value):
                for tgt in node.targets:
                    for t in (tgt.elts if isinstance(tgt, ast.Tuple)
                              else [tgt]):
                        if isinstance(t, ast.Name):
                            tainted.add(t.id)
                        elif isinstance(t, ast.Attribute):
                            d = _dotted(t)
                            if d:
                                tainted_attrs.add(d)
            if not isinstance(node, ast.Call):
                continue
            # record taint crossing into callees
            cn = _call_name(node)
            if cn in by_name:
                callee = by_name[cn]
                pnames = [a.arg for a in callee.node.args.args
                          if a.arg not in ("self", "cls")]
                for i, arg in enumerate(node.args):
                    if i < len(pnames) and is_tainted(arg):
                        callee.tainted_params.add(pnames[i])
            if not emit:
                continue
            d = _dotted(node.func)
            viol: Optional[str] = None
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_ATTRS:
                viol = f".{node.func.attr}() forces a host sync"
            elif d in _SYNC_DOTTED:
                viol = f"{d}() forces a host sync"
            elif ((d in _CONV_DOTTED
                   or (isinstance(node.func, ast.Name)
                       and node.func.id in _CONV_NAMES))
                  and node.args and is_tainted(node.args[0])):
                label = d or node.func.id  # type: ignore[union-attr]
                viol = (f"{label}() on a jitted-dispatch result blocks "
                        "on device compute")
            if viol is not None:
                out.append(Finding(
                    "SWAN102", rel, node.lineno, node.col_offset,
                    f"{viol} inside {f.qual}, which is reachable from the "
                    "per-step serve loop — keep the hot path async "
                    "(allowlisted fetch points: "
                    f"{', '.join(HOST_FETCH_ALLOWLIST)})",
                    snippet=_snippet(lines, node.lineno)))
        return out

    hot_fns = [f for f in fns if f.name in hot]
    # two silent passes to reach a taint fixpoint across call sites,
    # then one emitting pass
    for _ in range(2):
        for f in hot_fns:
            analyze(f, emit=False)
    out: List[Finding] = []
    for f in hot_fns:
        if f.name in HOST_FETCH_ALLOWLIST:
            continue
        out.extend(analyze(f, emit=True))
    return out


# ---------------------------------------------------------------------------
# SWAN103 — shape bucketing
# ---------------------------------------------------------------------------

def _rule_bucketing(tree: ast.Module, rel: str, lines) -> List[Finding]:
    rel_n = rel.replace("\\", "/")
    if not any(seg in rel_n for seg in ("/runtime/", "/models/")) \
            and not rel_n.startswith(("runtime/", "models/")):
        return []
    out: List[Finding] = []
    for f in _function_index(tree):
        if not _DISPATCH_FN_RE.search(f.name):
            continue
        for node in ast.walk(f.node):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func) or ""
            tail = d.rsplit(".", 1)[-1]
            if tail not in _CTOR_DOTTED_TAILS or "." not in d:
                continue
            if not node.args:
                continue
            shape = node.args[0] if tail != "broadcast_to" else (
                node.args[1] if len(node.args) > 1 else None)
            if shape is None:
                continue
            dims = (shape.elts if isinstance(shape, (ast.Tuple, ast.List))
                    else [shape])
            for dim in dims:
                if isinstance(dim, ast.Constant) \
                        and isinstance(dim.value, int) \
                        and dim.value > 1 and not _is_pow2(dim.value):
                    out.append(Finding(
                        "SWAN103", rel, dim.lineno, dim.col_offset,
                        f"literal dim {dim.value} in {d}(...) inside "
                        f"dispatch builder {f.qual} is not a power of two "
                        "— route it through a bucket (cf. _pow2/"
                        "_bucket_len) or the executable family grows per "
                        "shape", snippet=_snippet(lines, dim.lineno)))
    return out


# ---------------------------------------------------------------------------
# SWAN104 — spec completeness (cross-module; see lint_paths)
# ---------------------------------------------------------------------------

def extract_known_leaf_names(tree: ast.Module) -> Optional[Set[str]]:
    """Static read of ``KNOWN_LEAF_NAMES = (...)`` from serve_specs."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) \
                        and tgt.id == "KNOWN_LEAF_NAMES" \
                        and isinstance(node.value, (ast.Tuple, ast.List)):
                    return {e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)}
    return None


_ARRAY_CTOR_TAILS = ("zeros", "ones", "full", "empty", "broadcast_to",
                     "stack", "asarray", "arange", "concatenate")


def _is_array_ctor(value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    d = _dotted(value.func) or ""
    return "." in d and d.rsplit(".", 1)[-1] in _ARRAY_CTOR_TAILS


def extract_state_leaves(tree: ast.Module, rel: str
                         ) -> List[Tuple[str, int]]:
    """(leaf key, line) pairs for array-valued dict keys constructed by
    the state initialisers (functions matching ``init_*state`` /
    ``init_*cache`` / ``init_*pool`` / ``_side``).  Dict values that are
    themselves dicts or non-ctor calls are containers, not leaves."""
    out: List[Tuple[str, int]] = []
    for f in _function_index(tree):
        if not _STATE_INIT_RE.match(f.name):
            continue
        for node in ast.walk(f.node):
            if isinstance(node, ast.Dict):
                for key, value in zip(node.keys, node.values):
                    if isinstance(key, ast.Constant) \
                            and isinstance(key.value, str) \
                            and _is_array_ctor(value):
                        out.append((key.value, key.lineno))
            elif (isinstance(node, ast.Assign)
                  and len(node.targets) == 1
                  and isinstance(node.targets[0], ast.Subscript)
                  and isinstance(node.targets[0].slice, ast.Constant)
                  and isinstance(node.targets[0].slice.value, str)
                  and _is_array_ctor(node.value)):
                # d["idx"] = jnp.zeros(...) — the conditional-leaf idiom
                out.append((node.targets[0].slice.value,
                            node.targets[0].lineno))
    return out


def spec_completeness_findings(known: Set[str],
                               leaves_by_file: Dict[str, List[Tuple[str,
                                                                    int]]],
                               lines_by_file: Dict[str, Sequence[str]]
                               ) -> List[Finding]:
    out: List[Finding] = []
    for rel, leaves in sorted(leaves_by_file.items()):
        for name, line in leaves:
            if name not in known:
                out.append(Finding(
                    "SWAN104", rel, line, 0,
                    f"serve-state leaf {name!r} has no rule in "
                    "repro.sharding.serve_specs (KNOWN_LEAF_NAMES) — it "
                    "would ship replicated over a data mesh and every "
                    "shard would write the full array",
                    snippet=_snippet(lines_by_file.get(rel, []), line)))
    return out


# ---------------------------------------------------------------------------
# SWAN105 — obs hygiene
# ---------------------------------------------------------------------------

def _rule_obs(tree: ast.Module, rel: str, lines) -> List[Finding]:
    rel_n = rel.replace("\\", "/")
    if "/obs/" in rel_n or rel_n.startswith("obs/"):
        return []
    out: List[Finding] = []
    for node in ast.iter_child_nodes(tree):           # module level only
        if not isinstance(node, ast.Assign):
            continue
        val = node.value
        dictish = isinstance(val, (ast.Dict, ast.DictComp)) or (
            isinstance(val, ast.Call)
            and (_dotted(val.func) or "").rsplit(".", 1)[-1] in
            ("defaultdict", "Counter", "dict", "OrderedDict"))
        if not dictish:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name) and _METRIC_NAME_RE.search(tgt.id):
                out.append(Finding(
                    "SWAN105", rel, node.lineno, node.col_offset,
                    f"module-level metrics container {tgt.id!r} bypasses "
                    "MetricsRegistry — mint instruments via the "
                    "registry getters (repro.obs.metrics."
                    "REGISTRY_GETTERS) so they reach the exposition "
                    "and the schema-drift guard",
                    snippet=_snippet(lines, node.lineno)))
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

_PER_FILE_RULES = (_rule_floor, _rule_host_sync, _rule_bucketing, _rule_obs)


def lint_source(text: str, rel: str) -> List[Finding]:
    """All per-file findings for one module (suppressions applied)."""
    lines = text.splitlines()
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding("SWAN100", rel, e.lineno or 0, 0,
                        f"file does not parse: {e.msg}")]
    findings: List[Finding] = []
    for rule in _PER_FILE_RULES:
        findings.extend(rule(tree, rel, lines))
    sup, bad = suppression_map(text, tree, rel)
    findings.extend(bad)
    return apply_suppressions(findings, sup)


def apply_suppressions(findings: List[Finding],
                       sup: Dict[int, Tuple[Set[str], str]]
                       ) -> List[Finding]:
    for f in findings:
        hit = sup.get(f.line)
        if hit and f.rule in hit[0]:
            f.suppressed = True
            f.justification = hit[1]
    return findings


def lint_paths(root: str, rel_paths: Iterable[str]) -> List[Finding]:
    """Lint a file set (paths relative to ``root``), including the
    cross-module spec-completeness rule."""
    import os

    findings: List[Finding] = []
    known: Optional[Set[str]] = None
    leaves_by_file: Dict[str, List[Tuple[str, int]]] = {}
    lines_by_file: Dict[str, Sequence[str]] = {}
    sups: Dict[str, Dict[int, Tuple[Set[str], str]]] = {}
    for rel in sorted(rel_paths):
        path = os.path.join(root, rel)
        try:
            with open(path, "r", encoding="utf-8") as fh:
                text = fh.read()
        except OSError:
            continue
        findings.extend(lint_source(text, rel))
        rel_n = rel.replace("\\", "/")
        try:
            tree = ast.parse(text)
        except SyntaxError:
            continue
        lines = text.splitlines()
        sups[rel], _ = suppression_map(text, tree, rel)
        if rel_n.endswith("sharding/serve_specs.py"):
            known = extract_known_leaf_names(tree)
        if rel_n.endswith(SPEC_STATE_MODULES):
            lv = extract_state_leaves(tree, rel)
            if lv:
                leaves_by_file[rel] = lv
                lines_by_file[rel] = lines
    if known is not None and leaves_by_file:
        extra = spec_completeness_findings(known, leaves_by_file,
                                           lines_by_file)
        for f in extra:
            apply_suppressions([f], sups.get(f.path, {}))
        findings.extend(extra)
    return findings
