"""swanlint CLI.

    python -m repro.analysis.lint                    # report findings
    python -m repro.analysis.lint --check            # CI gate: fail on NEW
    python -m repro.analysis.lint --check --audit-smoke
    python -m repro.analysis.lint --write-baseline   # accept current state

``--check`` compares active findings against the committed baseline
(``bench_out/LINT_BASELINE.json``) by line-number-free fingerprint and
exits non-zero only on findings NOT in the baseline (or on Layer 2 audit
failures) — so the gate flags exactly what a diff introduced.  Layer 1 is
dependency-free; ``--audit-smoke`` additionally builds the smoke-config
engine matrix and audits the compiled dispatches (needs jax).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.lint import (DEFAULT_BASELINE, load_baseline,
                                 make_report, new_findings, run_lint)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="swanlint: SWAN repo-invariant static analysis")
    ap.add_argument("--root", default=".", help="repo root to scan")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on findings not in the baseline / on "
                         "audit failures")
    ap.add_argument("--audit-smoke", action="store_true",
                    help="run the Layer 2 compiled-dispatch audit on the "
                         "smoke-config engine matrix (imports jax)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline JSON (default <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline")
    ap.add_argument("--report", default=None,
                    help="write the full JSON report to this path")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root)
    base_path = args.baseline or os.path.join(root, DEFAULT_BASELINE)
    findings = run_lint(root)

    audit_checks = None
    if args.audit_smoke:
        from repro.analysis.lint.audit import run_audit
        audit_checks = run_audit(smoke=True)

    baseline = load_baseline(base_path)
    report = make_report(findings, audit_checks, baseline)
    counts = report["counts"]

    if args.write_baseline:
        os.makedirs(os.path.dirname(base_path), exist_ok=True)
        with open(base_path, "w", encoding="utf-8") as fh:
            json.dump({"tool": "swanlint", "version": report["version"],
                       "findings": report["findings"]}, fh, indent=1,
                      sort_keys=True)
            fh.write("\n")
        print(f"baseline written: {base_path} "
              f"({counts['total']} finding(s))")

    if args.report:
        os.makedirs(os.path.dirname(os.path.abspath(args.report)),
                    exist_ok=True)
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=1, sort_keys=True)
            fh.write("\n")

    for f in findings:
        mark = "suppressed" if f.suppressed else "ACTIVE"
        print(f"{f.path}:{f.line}: {f.rule} [{mark}] {f.message}")
    if audit_checks is not None:
        for c in audit_checks:
            print(f"audit {c.check}: {c.status.upper()} {c.detail}")
    new = new_findings(findings, baseline)
    n_audit_fail = counts.get("audit_failures", 0)
    print(f"swanlint: {counts['total']} finding(s), "
          f"{counts['suppressed']} suppressed, {counts['active']} active, "
          f"{len(new)} new vs baseline"
          + (f", {n_audit_fail} audit failure(s)"
             if audit_checks is not None else ""))

    if args.check and (new or n_audit_fail):
        for f in new:
            print(f"NEW: {f.path}:{f.line}: {f.rule} {f.message}",
                  file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
