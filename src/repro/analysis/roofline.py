"""Roofline terms from dry-run records (TPU v5e constants).

  compute_s    = flops_per_device / 197 TFLOP/s (bf16)
  memory_s     = hbm_bytes_per_device / 819 GB/s
  collective_s = collective_bytes_per_device / 50 GB/s/link

All HLO-derived quantities are per-device (post-SPMD shapes), so the spec's
"X/(chips × bw)" is applied with per-chip numerators directly.  MODEL_FLOPS
uses the paper-spec formulas: 6·N·D (train) / 2·N·D (serve), N_active for
MoE; the ratio MODEL_FLOPS / (HLO_flops × chips) exposes remat/redundancy
waste (>1 means HLO under-counts — e.g. analyzer misses; <1 means extra
compiled compute such as recompute or attention FLOPs outside 6·N·D).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_ADVICE = {
    "compute": ("increase arithmetic efficiency: larger per-chip tiles "
                "(reduce model-axis sharding), fuse elementwise chains, "
                "or drop remat recompute"),
    "memory": ("cut HBM traffic: SWAN-compress the KV cache / quantize "
               "weights / enlarge fusion regions so activations stay on-chip"),
    "collective": ("reshard to shrink collectives: move the sharded axis, "
                   "overlap collectives with compute, or compress the wire "
                   "format (int8 gradient sync)"),
}


def model_flops(cfg, shape, n_tokens: Optional[int] = None) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch           # one new token per sequence
    return 2.0 * n_active * toks


def kernel_model_bytes(cfg, shape, swan) -> int:
    """Per-device HBM bytes the fused Pallas decode kernel streams: the
    packed payload + ring buffer + params, each exactly once (BlockSpec-
    derived — every input tile is fetched once per grid point and the grid
    covers the cache once).  This is the TPU-target number the XLA ref path
    upper-bounds."""
    n_dev = 256
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    B, S = shape.global_batch, shape.seq_len
    per_vec = swan.k_max * (1 if swan.quantize else 2)
    if swan.mode == "topk":
        per_vec += swan.k_max                      # int8 indices
    if swan.quantize:
        per_vec += 4
    sparse = 2 * n_attn * B * cfg.n_kv_heads * S * per_vec
    buf = 2 * n_attn * B * cfg.n_kv_heads * swan.buffer * cfg.d_head * 2
    params = cfg.n_active_params() * 2
    return (sparse + buf + params) // n_dev


def _sparse_side_bytes(Kv: int, S: int, k_max: int, quantized: bool) -> int:
    """HBM bytes ONE sequence's packed sparse side (k + v) streams through
    the fused kernels: values (f32, or int8 + f32 per-vector scale when
    quantized) and int8 winnow indices, each touched exactly once — the
    BlockSpec grid covers every [block_s, k_max] tile once per (b, kv)."""
    val = k_max * (1 if quantized else 4)
    idx = k_max
    scale = 4 if quantized else 0
    return 2 * Kv * S * (val + idx + scale)


def swan_decode_kernel_bytes(*, B: int, Kv: int, G: int, dh: int, S: int,
                             k_max: int, buffer: int,
                             quantized: bool) -> int:
    """Ideal per-call HBM traffic of the fused SWAN decode kernel (slab or
    paged — the paged gather streams the same pool tiles, just via the
    prefetched page table): packed sparse prefix + dense ring buffer read
    once, q in, o out.  The pure-JAX path upper-bounds this (it
    additionally materialises expanded [S, dh] rows in HBM)."""
    sparse = B * _sparse_side_bytes(Kv, S, k_max, quantized)
    ring = 2 * B * Kv * buffer * dh * 4 + B * buffer * 4      # +buf_pos
    q = B * Kv * G * dh * 4
    o = B * Kv * G * dh * 4
    return sparse + ring + q + o


def swan_chunk_kernel_bytes(*, B: int, Kv: int, Q: int, dh: int, S: int,
                            k_max: int, quantized: bool) -> int:
    """Ideal per-call HBM traffic of the bulk-chunk prefill stats kernel:
    the packed sparse prefix once, Q query rows in, (m, l, o_unnorm)
    stats out."""
    sparse = B * _sparse_side_bytes(Kv, S, k_max, quantized)
    q = B * Kv * Q * dh * 4
    stats = B * Kv * Q * (2 + dh) * 4
    return sparse + q + stats


def flash_kernel_bytes(*, B: int, H: int, Sq: int, Sk: int, dh: int,
                       dtype_bytes: int = 4) -> int:
    """Ideal per-call HBM traffic of causal flash prefill: q/k/v in, o out,
    each once (GQA re-reads of kv tiles stay in VMEM in the ideal model)."""
    Kv_bytes = 2 * B * Sk * dh * dtype_bytes            # per kv head pair
    return B * H * Sq * dh * dtype_bytes * 2 + Kv_bytes


def flash_kernel_flops(*, B: int, H: int, Sq: int, Sk: int, dh: int,
                       causal: bool = True) -> float:
    """MXU flops of flash attention: 2 matmuls of [Sq, dh] x [dh, Sk],
    halved by the causal mask."""
    f = 4.0 * B * H * Sq * Sk * dh
    return f / 2 if causal else f


def roofline_row(name: str, us_per_call: float, hbm_bytes: int,
                 flops: float = 0.0, **tags) -> Dict[str, Any]:
    """One per-kernel roofline table row: the memory-bound (or
    compute-bound) floor time from the ideal byte/flop model vs the
    measured call time.  ``achieved_fraction`` is fraction-of-peak on TPU;
    in interpret mode on CPU it is a tiny consistency number (the gate in
    benchmarks/bench_kernels.py keys its threshold off the backend)."""
    mem_s = hbm_bytes / HBM_BW
    comp_s = flops / PEAK_FLOPS
    bound = "compute" if comp_s > mem_s else "memory"
    floor_s = max(mem_s, comp_s)
    meas_s = us_per_call * 1e-6
    row = {"name": name, "us_per_call": float(us_per_call),
           "hbm_bytes": int(hbm_bytes), "flops": float(flops),
           "bound": bound, "floor_us": floor_s * 1e6,
           "achieved_bw_gbs": (hbm_bytes / meas_s / 1e9) if meas_s else 0.0,
           "achieved_fraction": (floor_s / meas_s) if meas_s else 0.0}
    row.update(tags)
    return row


def roofline_report(record: Dict[str, Any], cfg, shape,
                    swan=None) -> Dict[str, Any]:
    hlo = record["hlo_cost"]
    n_dev = record["n_devices"]
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["hbm_bytes"] / HBM_BW
    collective_s = hlo["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_hlo_flops = hlo["flops"] * n_dev
    step_s = max(terms.values())
    ideal_s = mf / (n_dev * PEAK_FLOPS)
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "roofline_fraction": ideal_s / step_s if step_s else 0.0,
        "advice": _ADVICE[bottleneck],
    }
    if swan is not None and shape.kind == "decode":
        kb = kernel_model_bytes(cfg, shape, swan)
        out["kernel_model_bytes"] = kb
        out["kernel_model_memory_s"] = kb / HBM_BW
    return out
