"""Roofline terms from dry-run records (TPU v5e constants).

  compute_s    = flops_per_device / 197 TFLOP/s (bf16)
  memory_s     = hbm_bytes_per_device / 819 GB/s
  collective_s = collective_bytes_per_device / 50 GB/s/link

All HLO-derived quantities are per-device (post-SPMD shapes), so the spec's
"X/(chips × bw)" is applied with per-chip numerators directly.  MODEL_FLOPS
uses the paper-spec formulas: 6·N·D (train) / 2·N·D (serve), N_active for
MoE; the ratio MODEL_FLOPS / (HLO_flops × chips) exposes remat/redundancy
waste (>1 means HLO under-counts — e.g. analyzer misses; <1 means extra
compiled compute such as recompute or attention FLOPs outside 6·N·D).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_ADVICE = {
    "compute": ("increase arithmetic efficiency: larger per-chip tiles "
                "(reduce model-axis sharding), fuse elementwise chains, "
                "or drop remat recompute"),
    "memory": ("cut HBM traffic: SWAN-compress the KV cache / quantize "
               "weights / enlarge fusion regions so activations stay on-chip"),
    "collective": ("reshard to shrink collectives: move the sharded axis, "
                   "overlap collectives with compute, or compress the wire "
                   "format (int8 gradient sync)"),
}


def model_flops(cfg, shape, n_tokens: Optional[int] = None) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch           # one new token per sequence
    return 2.0 * n_active * toks


def kernel_model_bytes(cfg, shape, swan) -> int:
    """Per-device HBM bytes the fused Pallas decode kernel streams: the
    packed payload + ring buffer + params, each exactly once (BlockSpec-
    derived — every input tile is fetched once per grid point and the grid
    covers the cache once).  This is the TPU-target number the XLA ref path
    upper-bounds."""
    n_dev = 256
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    B, S = shape.global_batch, shape.seq_len
    per_vec = swan.k_max * (1 if swan.quantize else 2)
    if swan.mode == "topk":
        per_vec += swan.k_max                      # int8 indices
    if swan.quantize:
        per_vec += 4
    sparse = 2 * n_attn * B * cfg.n_kv_heads * S * per_vec
    buf = 2 * n_attn * B * cfg.n_kv_heads * swan.buffer * cfg.d_head * 2
    params = cfg.n_active_params() * 2
    return (sparse + buf + params) // n_dev


def roofline_report(record: Dict[str, Any], cfg, shape,
                    swan=None) -> Dict[str, Any]:
    hlo = record["hlo_cost"]
    n_dev = record["n_devices"]
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["hbm_bytes"] / HBM_BW
    collective_s = hlo["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    total_hlo_flops = hlo["flops"] * n_dev
    step_s = max(terms.values())
    ideal_s = mf / (n_dev * PEAK_FLOPS)
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_flops_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "roofline_fraction": ideal_s / step_s if step_s else 0.0,
        "advice": _ADVICE[bottleneck],
    }
    if swan is not None and shape.kind == "decode":
        kb = kernel_model_bytes(cfg, shape, swan)
        out["kernel_model_bytes"] = kb
        out["kernel_model_memory_s"] = kb / HBM_BW
    return out
