"""Fault-tolerant checkpointing: atomic, async, keep-last-k, reshardable.

Layout (one directory per step):

    <dir>/step_000123.tmp/...   (written)
    <dir>/step_000123/          (atomic rename on completion)
        manifest.json           {step, tree structure, shapes, dtypes, crc}
        arr_00000.npy ...       one file per leaf (np.save)

Guarantees:
  * atomicity — a checkpoint directory either exists completely or not at
    all (tmp+rename; interrupted saves leave only .tmp litter, cleaned on
    next save),
  * integrity — CRC32 per leaf, verified on restore,
  * async     — ``save(..., blocking=False)`` snapshots to host memory
    synchronously, writes on a daemon thread (training continues),
  * keep-k    — old steps garbage-collected after a successful save,
  * elastic restore — arrays are plain host numpy; the caller re-shards onto
    whatever mesh is current (``jax.device_put(tree, shardings)``), so a run
    can resume on a different topology (DESIGN.md §4 elasticity).

Multi-host: every host saves its addressable shards under
``host_<id>/``; restore concatenates per the saved global shape.  On this
single-process container that collapses to host_0 with full arrays.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Tree) -> Tuple[List[np.ndarray], Any, List[str]]:
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(getattr(k, "key", str(k)) for k in path)
             for path, _ in flat]
    arrays = [np.asarray(leaf) for _, leaf in flat]
    return arrays, tdef, paths


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Tree, blocking: bool = True) -> None:
        self.wait()   # one in-flight save at a time
        arrays, tdef, paths = _flatten(tree)
        treedef_repr = jax.tree_util.tree_structure(tree)
        if blocking:
            self._write(step, arrays, paths, str(treedef_repr))
        else:
            self._thread = threading.Thread(
                target=self._write_guarded,
                args=(step, arrays, paths, str(treedef_repr)), daemon=True)
            self._thread.start()

    def _write_guarded(self, *args) -> None:
        try:
            self._write(*args)
        except BaseException as e:   # surfaced on next wait()
            self._error = e

    def _write(self, step: int, arrays, paths, treedef_repr: str) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "paths": paths, "leaves": [], "version": 1}
        for i, arr in enumerate(arrays):
            # raw-bytes storage: exotic dtypes (bfloat16, fp8) round-trip
            # losslessly where np.save would fall over
            fn = f"arr_{i:05d}.bin"
            raw = np.ascontiguousarray(arr).tobytes()
            with open(os.path.join(tmp, fn), "wb") as f:
                f.write(raw)
            manifest["leaves"].append({
                "file": fn, "path": paths[i],
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc": zlib.crc32(raw),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from e

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
        for d in os.listdir(self.dir):   # orphaned tmp from crashes
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Tree,
                shardings: Optional[Tree] = None) -> Tree:
        """Restore into the structure of ``like`` (shape/dtype-checked).

        ``shardings``: optional matching tree of Shardings — enables elastic
        resume onto a different mesh (device_put with the target sharding).
        """
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = []
        for leaf in manifest["leaves"]:
            with open(os.path.join(d, leaf["file"]), "rb") as f:
                raw = f.read()
            crc = zlib.crc32(raw)
            if crc != leaf["crc"]:
                raise IOError(f"checkpoint corruption in {leaf['path']}: "
                              f"crc {crc} != {leaf['crc']}")
            dtype = _resolve_dtype(leaf["dtype"])
            arrays.append(np.frombuffer(raw, dtype=dtype).reshape(
                leaf["shape"]).copy())
        flat_like, tdef = jax.tree_util.tree_flatten(like)
        if len(flat_like) != len(arrays):
            raise ValueError(f"leaf count mismatch: ckpt {len(arrays)} "
                             f"vs target {len(flat_like)}")
        for a, l, meta in zip(arrays, flat_like, manifest["leaves"]):
            if tuple(a.shape) != tuple(l.shape):
                raise ValueError(f"shape mismatch at {meta['path']}: "
                                 f"{a.shape} vs {l.shape}")
        cast = [a.astype(l.dtype) if str(a.dtype) != str(l.dtype) else a
                for a, l in zip(arrays, flat_like)]
        tree = jax.tree_util.tree_unflatten(tdef, cast)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree
