"""Activation-sharding hooks.

Model code is mesh-agnostic: it calls ``shard(x, kind)`` at well-known
points; the launcher installs a ``ShardingRules`` mapping kinds to
``NamedSharding``s.  With no rules installed (CPU tests) the hooks are
no-ops, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


class ShardingRules:
    def __init__(self, mesh: Mesh, kinds: Dict[str, P]):
        self.mesh = mesh
        self.kinds = kinds

    def sharding(self, kind: str) -> Optional[NamedSharding]:
        spec = self.kinds.get(kind)
        if spec is None:
            return None
        return NamedSharding(self.mesh, spec)


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard(x, kind: str):
    """Apply a sharding constraint if rules are installed; else identity."""
    rules = current_rules()
    if rules is None:
        return x
    s = rules.sharding(kind)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def shard_map_compat(fn, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking off:
    jax.shard_map (>= 0.4.35, ``check_vma``) falling back to
    jax.experimental.shard_map (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
