"""PartitionSpecs for serving state (KV caches, SWAN hybrid caches,
recurrent states) and serve-step inputs.

Decode distribution (DESIGN.md §4): caches shard batch over ('pod','data')
and the *sequence* dim over 'model' — flash-decoding-style split-S, valid
for any head count (incl. GQA kv < mesh) and any batch (axes that don't
divide, or that the mesh doesn't carry, are dropped by the sanitizer —
e.g. long_500k's batch=1, or the serve engine's data-only mesh).

The sharded serve engine (``repro.runtime.serve_engine`` with ``mesh=``)
builds its ``shard_map`` in/out specs from ``serve_state_pspecs``: every
serve-state leaf MUST therefore have an explicit rule here — an unknown
leaf would silently ship replicated, which on a data mesh means every
shard carries (and writes!) the full array.  ``unspecced_serve_leaves``
exposes the leaves that would fall through to the replicated fallback so
tests can assert completeness (tests/test_sharding.py)."""
from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.specs import dp_axes

# Every serve-state leaf name that has an explicit PartitionSpec rule in
# ``_leaf_spec_raw`` below.  Declarative on purpose: swanlint's
# spec-completeness rule (SWAN104, ``repro.analysis.lint``) reads this
# tuple STATICALLY and cross-checks it against the leaf keys constructed
# by the cache/state initialisers (``core.hybrid_cache``,
# ``core.paged_cache``, ``models.attention`` …), so a new serve-state
# leaf cannot land without a sharding decision here — the static twin of
# the ``unspecced_serve_leaves`` runtime check.
KNOWN_LEAF_NAMES = ("vals", "idx", "scale", "k", "v", "buf_k", "buf_v",
                    "buf_pos", "h", "conv", "S", "x_tm", "x_cm")


def _sanitize(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on axes the mesh doesn't carry or that don't divide
    the dim size."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        if any(a not in mesh.shape for a in axes):
            out.append(None)
            continue
        n = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if (dim % n == 0 and dim >= n) else None)
    return P(*out)


def sanitize_tree(specs, tree, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s, x: _sanitize(s, x.shape, mesh), specs, tree)


def _leaf_spec_raw(name: str, ndim: int) -> Optional[P]:
    """Spec for a serve-state leaf by path name, over the PRODUCTION axis
    names ('pod'/'data' for batch-like dims, 'model' for sequence-like
    dims) — the sanitizer drops whatever a concrete mesh can't carry.
    Returns None for leaves with no explicit rule (see
    ``unspecced_serve_leaves``)."""
    dp = ("pod", "data")
    leaf = name.split("/")[-1]
    if leaf not in KNOWN_LEAF_NAMES:     # keep the declarative tuple honest
        return None
    # stacked caches have a leading layer/group axis (never sharded)
    if "pool/" in name:
        # paged sparse pool [L,n_pages,Kv,ps,k]: the page axis plays the
        # role batch has in the slab layout (a page belongs to one slot, a
        # slot to one data shard — see repro.runtime.page_pool's per-shard
        # blocks) and within-page rows are the sequence dim — so the pool
        # shards over the same mesh axes as the slab sparse leaves: pages
        # over dp, page rows over 'model'.  (The page TABLE is a host-owned
        # jit operand, not serve state; the sharded engine ships it batch-
        # sharded with shard-local physical indices.)
        if leaf in ("vals", "idx"):
            return P(None, dp, None, "model", None)
        if leaf == "scale":              # [L,n_pages,Kv,ps]
            return P(None, dp, None, "model")
    if leaf in ("vals", "idx"):          # [L,B,Kv,S,k] packed sparse
        return P(None, dp, None, "model", None)
    if leaf == "scale":                  # [L,B,Kv,S]
        return P(None, dp, None, "model")
    if leaf in ("k", "v"):               # [L,B,Kv,S,dh] dense cache
        return P(None, dp, None, "model", None)
    if leaf in ("buf_k", "buf_v"):       # [L,B,Kv,b,dh] ring buffer
        return P(None, dp, None, None, None)
    if leaf == "buf_pos":                # [L,B,b]
        return P(None, dp, None)
    if leaf == "h":                      # mamba state [G,B,d_in,N]
        return P(None, dp, "model", None)
    if leaf == "conv":                   # mamba conv tail [G,B,c,d_in]
        return P(None, dp, None, "model")
    if leaf == "S":                      # rwkv state [L,B,H,dk,dv]
        return P(None, dp, None, None, None)
    if leaf in ("x_tm", "x_cm"):         # rwkv shifts [L,B,1,d]
        return P(None, dp, None, None)
    return None


def _leaf_spec(name: str, ndim: int, mesh: Mesh) -> P:
    spec = _leaf_spec_raw(name, ndim)
    if spec is None:
        return P(*([None] * ndim))
    # collapse the production dp tuple to what this mesh carries (the
    # sanitizer then drops axes that don't divide or don't exist)
    dp = dp_axes(mesh)
    return P(*[dp if ax == ("pod", "data") else ax for ax in tuple(spec)])


def _walk(state):
    flat, tdef = jax.tree_util.tree_flatten_with_path(state)
    named = [("/".join(getattr(k, "key", str(k)) for k in path), leaf)
             for path, leaf in flat]
    return named, tdef


def unspecced_serve_leaves(state) -> List[str]:
    """Names of serve-state leaves that have NO explicit spec rule and
    would silently ship replicated over a data mesh.  Tests assert this is
    empty for every engine state layout so new leaves can't land without a
    sharding decision."""
    named, _ = _walk(state)
    return [name for name, leaf in named
            if _leaf_spec_raw(name, leaf.ndim) is None]


def serve_state_pspecs(state, mesh: Mesh):
    named, tdef = _walk(state)
    specs = [_sanitize(_leaf_spec(name, leaf.ndim, mesh), leaf.shape, mesh)
             for name, leaf in named]
    return jax.tree_util.tree_unflatten(tdef, specs)


def serve_state_shardings(state, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  serve_state_pspecs(state, mesh))


def batch_pspecs(batch, mesh: Mesh):
    dp = dp_axes(mesh)
    return jax.tree_util.tree_map(
        lambda x: _sanitize(P(dp, *([None] * (x.ndim - 1))), x.shape, mesh),
        batch)


def batch_shardings(batch, mesh: Mesh):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  batch_pspecs(batch, mesh))
