"""PartitionSpec rules: parameters, optimizer state, activations, caches.

Three tensor-parallel styles (ModelConfig.tp_style):

  * ``heads``       — classic TP: attention heads / FFN hidden / vocab over
                      'model'; optional FSDP over 'data' (fsdp_data) for the
                      405B-class configs; optional sequence sharding of the
                      residual stream over 'model' (seq_shard).
  * ``fsdp_model``  — tiny archs whose head counts don't divide the mesh
                      (whisper-small 12H, internvl2 14H): the 'model' axis is
                      used as a ZeRO-3 storage axis (params sharded on their
                      largest dim, gathered at use); activations stay
                      batch-sharded over 'data'.

Data parallelism always spans ('pod', 'data') when the pod axis exists.

Parameter specs are resolved by leaf *path name* so the same table covers
every architecture; stacked (scan-over-layers) parameter trees get the
leading layer axis unsharded automatically (specs are matched to the
trailing dims).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.api import ShardingRules


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

# (suffix match on leaf path) -> spec over the leaf's TRAILING dims, by style.
# "D" marks where the fsdp_data axis goes (replaced by 'data' when enabled).
_HEADS_TABLE = {
    "embed":      ("model", "D"),
    "pos_embed":  (None, "D"),
    "head":       ("D", "model"),
    "wq":         ("D", "model"),
    "wk":         ("D", "model"),
    "wv":         ("D", "model"),
    "wo":         ("model", "D"),
    "bq":         ("model",),
    "bk":         ("model",),
    "bv":         ("model",),
    "bo":         (None,),
    "w_gate":     ("D", "model"),
    "w_up":       ("D", "model"),
    "w_down":     ("model", "D"),
    "b_up":       ("model",),
    "b_down":     (None,),
    "router":     (None, None),
    "scale":      (None,),
    "bias":       (None,),
    # mamba
    "w_in":       ("D", "model"),
    "conv_w":     (None, "model"),
    "conv_b":     ("model",),
    "w_x":        ("model", "D"),
    "w_dt":       ("D", "model"),
    "dt_bias":    ("model",),
    "a_log":      ("model", None),
    "d_skip":     ("model",),
    "w_out":      ("model", "D"),
    # rwkv
    "w_r":        ("D", "model"),
    "w_k6":       ("D", "model"),
    "w_v6":       ("D", "model"),
    "w_g":        ("D", "model"),
    "w_o6":       ("model", "D"),
    "decay_w":    ("model",),
    "bonus_u":    ("model",),
    "mix":        (None, None),
    "decay_lora_a": ("D", None),
    "decay_lora_b": (None, "model"),
}

# MoE expert tensors (leading E axis).  EP ('model' on E) when divisible,
# otherwise TP on the expert-hidden dim.
_MOE_EP = {
    "w_gate": ("model", "D", None),
    "w_up":   ("model", "D", None),
    "w_down": ("model", None, "D"),
}
_MOE_TP = {
    "w_gate": (None, "D", "model"),
    "w_up":   (None, "D", "model"),
    "w_down": (None, "model", "D"),
}


def _leaf_spec(path: str, shape: Tuple[int, ...], cfg, mesh: Mesh) -> P:
    names = path.split("/")
    leaf = names[-1]
    is_expert = "experts" in names
    style = cfg.tp_style

    if style == "fsdp_model":
        # ZeRO-3 storage: shard the largest trailing dim over ('model',)
        # (+ 'data' is unused for storage on tiny archs).
        if len(shape) == 0:
            return P()
        trailing = list(shape)
        big = int(np.argmax(trailing))
        axes = [None] * len(trailing)
        if trailing[big] % mesh.shape["model"] == 0 and trailing[big] >= mesh.shape["model"]:
            axes[big] = "model"
        return P(*axes)

    table = _HEADS_TABLE
    if is_expert and leaf in _MOE_EP:
        table_entry = (_MOE_EP if cfg.moe.shard_experts else _MOE_TP)[leaf]
    else:
        table_entry = table.get(leaf)
        if table_entry is None:
            return P(*([None] * len(shape)))
    spec = []
    for ax in table_entry:
        if ax == "D":
            spec.append("data" if cfg.fsdp_data else None)
        else:
            spec.append(ax)
    # stacked-layer leading axes: pad with None on the left
    while len(spec) < len(shape):
        spec.insert(0, None)
    spec = spec[-len(shape):] if len(spec) > len(shape) else spec
    # drop shardings that don't divide the dim (uneven shardings are legal in
    # GSPMD but we keep clean tiles wherever we can)
    clean = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            clean.append(None)
        else:
            n = int(np.prod([mesh.shape[a] for a in ((ax,) if isinstance(ax, str) else ax)]))
            clean.append(ax if dim % n == 0 else None)
    return P(*clean)


def params_pspecs(params, cfg, mesh: Mesh):
    """Tree of PartitionSpec matching a parameter tree."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        name = "/".join(getattr(k, "key", str(k)) for k in path)
        specs.append(_leaf_spec(name, leaf.shape, cfg, mesh))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def params_shardings(params, cfg, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), params_pspecs(params, cfg, mesh))


# ---------------------------------------------------------------------------
# Activation rules
# ---------------------------------------------------------------------------

def activation_rules(cfg, mesh: Mesh) -> ShardingRules:
    dp = dp_axes(mesh)
    tp_ok = cfg.tp_style == "heads"
    seq = "model" if (cfg.seq_shard and tp_ok) else None
    heads_ax = "model" if (tp_ok and (cfg.n_heads * cfg.d_head) % mesh.shape["model"] == 0
                           and cfg.n_heads % mesh.shape["model"] == 0) else None
    ff_ax = "model" if (tp_ok and cfg.d_ff % mesh.shape["model"] == 0) else None
    kinds: Dict[str, P] = {
        "tokens":     P(dp, None),
        "residual":   P(dp, seq, None),
        # seq-sharded archs keep logits sharded on seq; otherwise vocab-TP
        "logits":     P(dp, seq, None) if seq else P(dp, None, "model" if tp_ok else None),
        "attn_q":     P(dp, None, heads_ax, None),
        "attn_kv":    P(dp, None, None, None),
        "attn_out":   P(dp, None, heads_ax, None),
        "ffn_hidden": P(dp, None, ff_ax),
        # decode-time: KV cache sequence dim over 'model' (flash-decoding
        # style split-S — works for any head count, incl. GQA kv<mesh)
        "kv_cache":   P(dp, None, "model", None),
        "swan_sparse": P(dp, None, "model", None),
        "swan_scale": P(dp, None, "model"),
        "swan_buf":   P(dp, None, None, None),
        "decode_q":   P(dp, None, None, None),
        # mamba: channel parallel
        "mamba_inner": P(dp, None, "model" if tp_ok else None),
        "mamba_state": P(dp, "model" if tp_ok else None, None),
        # rwkv: head-state parallel when divisible
        "rwkv_state": P(dp, None, None, None),
        "moe_buffer": P("model" if (cfg.moe and cfg.moe.shard_experts) else None,
                        None, None),
        "prefix":     P(dp, None, None),
        "enc_out":    P(dp, None, None),
    }
    return ShardingRules(mesh, kinds)
