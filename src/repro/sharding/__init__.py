from repro.sharding.api import ShardingRules, shard, use_rules  # noqa: F401
from repro.sharding.specs import (activation_rules, dp_axes,  # noqa: F401
                                  params_pspecs, params_shardings)
