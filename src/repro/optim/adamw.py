"""AdamW in pure JAX (no optax): decoupled weight decay, global-norm grad
clipping, warmup + cosine decay, configurable moment dtype.

State-dtype compression (``OptimizerConfig.state_dtype='bfloat16'``) halves
optimizer memory for the 405B-class configs — one of the distributed-
optimization tricks listed in DESIGN.md §4.  Moments are stored in the
configured dtype but *updated* in float32 (compute-precision decoupled from
storage-precision, same pattern as mixed-precision training).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_opt_state(params: Params, opt_cfg) -> Params:
    sdt = jnp.dtype(opt_cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, sdt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step, opt_cfg) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = opt_cfg.lr * (step + 1.0) / max(opt_cfg.warmup_steps, 1)
    prog = jnp.clip((step - opt_cfg.warmup_steps) /
                    max(opt_cfg.decay_steps - opt_cfg.warmup_steps, 1), 0.0, 1.0)
    cos = opt_cfg.min_lr_ratio + (1 - opt_cfg.min_lr_ratio) * \
        0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < opt_cfg.warmup_steps, warm, opt_cfg.lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


_NO_DECAY = ("scale", "bias", "ln", "dt_bias", "decay_w", "bonus_u", "mix",
             "gn_scale", "gn_bias", "a_log", "d_skip")


def _decay_mask(params) -> Params:
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(getattr(k, "key", str(k)) for k in path)
        decay = leaf.ndim >= 2 and not any(t in name for t in _NO_DECAY)
        out.append(jnp.asarray(1.0 if decay else 0.0, jnp.float32))
    return jax.tree_util.tree_unflatten(tdef, out)


def adamw_update(params: Params, grads: Params, state: Params, opt_cfg
                 ) -> Tuple[Params, Params, Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (params, state, metrics)."""
    grads, gn = clip_by_global_norm(grads, opt_cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_schedule(state["step"], opt_cfg)
    b1, b2 = opt_cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)
    sdt = jnp.dtype(opt_cfg.state_dtype)

    def upd(p, g, m, v, dmask):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + opt_cfg.eps)
        update = update + opt_cfg.weight_decay * dmask * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"], mask)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
