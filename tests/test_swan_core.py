"""SWAN core behaviour: Lemma A.1/A.2 losslessness, winnow/pack, hybrid
cache semantics, end-to-end full-retention exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.core import hybrid_cache as hc
from repro.core import projections as proj
from repro.core import swan_attention as swa
from repro.core.winnow import (dequantize_int8, quantize_int8, rotate_k,
                               rotate_q, topk_pack, truncate_pack,
                               unpack_dense)
from repro.models import transformer as tfm


@pytest.fixture(scope="module")
def calibrated():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    params = tfm.init_lm_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    q, k, v, wo = tfm.collect_qkv(params, cfg, tokens)
    pj = proj.compute_projections((q, k, v), wo, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.d_head)
    absorbed = tfm.absorb_swan(params, cfg, pj)
    return cfg, params, absorbed, pj, tokens


def test_rotation_preserves_scores_lemma_a1(calibrated):
    """Lemma A.1: q̂·k̂ᵀ == q·kᵀ for orthogonal P_QK."""
    cfg, params, _, pj, tokens = calibrated
    q, k, v, _ = tfm.collect_qkv(params, cfg, tokens)
    l = 0
    p_qk = pj["p_qk"][l]
    qh = rotate_q(q[l], p_qk, cfg.n_kv_heads)        # [B,S,Kv,G,dh]
    kh = rotate_k(k[l], p_qk)
    B, S, Kv, G, dh = qh.shape
    s_rot = jnp.einsum("bsjgd,btjd->bjgst", qh, kh)
    q_grouped = q[l].reshape(B, S, Kv, G, dh)
    s_orig = jnp.einsum("bsjgd,btjd->bjgst", q_grouped, k[l])
    np.testing.assert_allclose(np.asarray(s_rot), np.asarray(s_orig),
                               atol=5e-4, rtol=1e-3)


def test_absorption_lossless_lemma_a2(calibrated):
    """Lemma A.2: absorbed Ŵ_V/Ŵ_O give identical logits."""
    cfg, params, absorbed, _, tokens = calibrated
    lg1, _ = tfm.lm_forward(params, cfg, tokens)
    lg2, _ = tfm.lm_forward(absorbed, cfg, tokens)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               atol=2e-4, rtol=1e-3)


def test_full_retention_serving_exact(calibrated):
    """k_max = d_head keeps SWAN serving bit-comparable to dense serving."""
    cfg, params, absorbed, pj, tokens = calibrated
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")
    sc = tfm.init_caches(cfg, swan, 2, 48)
    dc = tfm.init_caches(cfg, None, 2, 48)
    lg_s, sc = tfm.lm_prefill(absorbed, cfg, tokens, sc, swan, pj)
    lg_d, dc = tfm.lm_prefill(params, cfg, tokens, dc)
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_d), atol=2e-4,
                               rtol=1e-3)
    tok = jnp.argmax(lg_d[:, -1], -1)
    for i in range(12):   # through buffer eviction (b=8)
        lg_s, sc = tfm.lm_decode_step(absorbed, cfg, tok, 24 + i, sc, swan, pj)
        lg_d, dc = tfm.lm_decode_step(params, cfg, tok, 24 + i, dc)
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_d),
                                   atol=5e-4, rtol=1e-3)
        tok = jnp.argmax(lg_d, -1)


# ---------------------------------------------------------------------------
# Winnowing primitives
# ---------------------------------------------------------------------------

def test_topk_pack_roundtrip_full_k():
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 16))
    vals, idx = topk_pack(x, 16)
    np.testing.assert_allclose(np.asarray(unpack_dense(vals, idx, 16)),
                               np.asarray(x), atol=0)


def test_topk_pack_keeps_largest():
    x = jnp.asarray([[1.0, -5.0, 0.1, 3.0]])
    vals, idx = topk_pack(x, 2)
    assert set(np.asarray(idx[0]).tolist()) == {1, 3}
    dense = unpack_dense(vals, idx, 4)
    np.testing.assert_allclose(np.asarray(dense), [[0.0, -5.0, 0.0, 3.0]])


def test_runtime_k_active_zeroes_tail():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    vals, idx = topk_pack(x, 8, k_active=jnp.asarray(3))
    assert bool(jnp.all(vals[:, 3:] == 0))
    assert not bool(jnp.all(vals[:, :3] == 0))


def test_truncate_pack():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    vals = truncate_pack(x, 6)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(x[:, :6]))
    dense = unpack_dense(vals, None, 16)
    assert dense.shape == (4, 16)
    assert bool(jnp.all(dense[:, 6:] == 0))


def test_quantize_int8_bound():
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 64)) * 3
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    err = jnp.abs(deq - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool(jnp.all(err <= bound * 0.5 + 1e-6))


# ---------------------------------------------------------------------------
# Hybrid cache semantics
# ---------------------------------------------------------------------------

def test_prefill_then_decode_equals_all_prefill():
    """Cache built by prefill(S) + decode == cache built by prefill(S+1)."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    key = jax.random.PRNGKey(0)
    S = 11
    kh = jax.random.normal(key, (1, S + 1, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.PRNGKey(9), (1, S + 1, cfg.n_kv_heads, cfg.d_head))

    c1 = hc.init_swan_cache(cfg, swan, 1, 32)
    c1 = hc.swan_cache_insert_prefill(c1, swan, cfg, kh, vh)

    c2 = hc.init_swan_cache(cfg, swan, 1, 32)
    c2 = hc.swan_cache_insert_prefill(c2, swan, cfg, kh[:, :S], vh[:, :S])
    c2 = hc.swan_cache_insert_decode(c2, swan, cfg, kh[:, S:], vh[:, S:], S)

    # sparse region [0, S+1-b) and buffer contents must agree
    n_sp = S + 1 - swan.buffer
    np.testing.assert_allclose(np.asarray(c1["k"]["vals"][:, :, :n_sp]),
                               np.asarray(c2["k"]["vals"][:, :, :n_sp]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(c1["k"]["idx"][:, :, :n_sp]),
                               np.asarray(c2["k"]["idx"][:, :, :n_sp]))
    order1 = np.argsort(np.asarray(c1["buf_pos"][0]))
    order2 = np.argsort(np.asarray(c2["buf_pos"][0]))
    np.testing.assert_allclose(
        np.asarray(c1["buf_k"])[:, :, order1],
        np.asarray(c2["buf_k"])[:, :, order2], atol=1e-6)


def test_ring_buffer_eviction_order():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=4, buffer=4, mode="topk")
    cache = hc.init_swan_cache(cfg, swan, 1, 16)
    for pos in range(10):
        k1 = jnp.full((1, 1, cfg.n_kv_heads, cfg.d_head), float(pos + 1))
        cache = hc.swan_cache_insert_decode(cache, swan, cfg, k1, k1, pos)
    bp = np.asarray(cache["buf_pos"][0])
    assert sorted(bp.tolist()) == [6, 7, 8, 9]       # last b=4 positions
    assert int(hc.sparse_len(swan, 9)) == 6           # 0..5 winnowed


def test_per_sequence_ring_positions():
    """Regression: two sequences decoding at different positions must track
    independent ring state ([B, b] buf_pos) and mask validity per sequence.
    Before the fix buf_pos was a single [b] vector shared across the batch,
    so the second sequence's eviction clock corrupted the first's."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=cfg.d_head, buffer=4, mode="topk")
    B, S = 2, 32
    key = jax.random.PRNGKey(0)
    kh = jax.random.normal(key, (B, 1, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, 1, cfg.n_kv_heads, cfg.d_head))

    # seq 0 decodes positions 0..9 (ring fills then wraps once), seq 1 is 7
    # tokens ahead at 7..16 (ring wrapped repeatedly) — one batched insert
    # call per step serves both
    offset = [0, 7]
    cache = hc.init_swan_cache(cfg, swan, B, S)
    single = [hc.init_swan_cache(cfg, swan, 1, S) for _ in range(B)]
    for step in range(10):
        pos_b = jnp.asarray([step + offset[0], step + offset[1]], jnp.int32)
        k_step = kh + float(step)
        v_step = vh - float(step)
        cache = hc.swan_cache_insert_decode(cache, swan, cfg, k_step, v_step,
                                            pos_b)
        for i in range(B):
            single[i] = hc.swan_cache_insert_decode(
                single[i], swan, cfg, k_step[i:i + 1], v_step[i:i + 1],
                step + offset[i])
    pos_each = [9 + offset[0], 9 + offset[1]]

    assert cache["buf_pos"].shape == (B, swan.buffer)
    for i in range(B):
        np.testing.assert_array_equal(np.asarray(cache["buf_pos"][i]),
                                      np.asarray(single[i]["buf_pos"][0]))

    # batched attention at mixed positions == each sequence attended alone
    q = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    pos_b = jnp.asarray(pos_each, jnp.int32)
    o_batch = swa.swan_decode_attention(q, cache, swan, cfg, pos_b)
    for i in range(B):
        o_one = swa.swan_decode_attention(q[i:i + 1], single[i], swan, cfg,
                                          pos_each[i])
        np.testing.assert_allclose(np.asarray(o_batch[i:i + 1]),
                                   np.asarray(o_one), atol=1e-6)
        ref = swa.swan_decode_attention_reference(q[i:i + 1], single[i],
                                                  swan, cfg, pos_each[i])
        np.testing.assert_allclose(np.asarray(o_batch[i:i + 1]),
                                   np.asarray(ref), atol=1e-5)


def test_cache_bytes_matches_eq1():
    cfg = get_smoke_config("llama3-8b")
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    got = hc.cache_bytes(cfg, swan, batch=2, max_seq=32)
    per_vec = 8 * 2 + 8                              # bf16 vals + int8 idx
    expect = 2 * 2 * cfg.n_kv_heads * 32 * per_vec + \
        2 * 2 * cfg.n_kv_heads * 4 * cfg.d_head * 2
    assert got == expect
