"""Paged sparse KV cache end-to-end: a paged mixed-length / mixed-k engine
run is token-identical to the slab engine, live bytes track generated
tokens (and are reclaimed on retirement), prompt bucketing bounds prefill
compilations, and pool exhaustion surfaces cleanly."""
import jax
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.page_pool import PagePoolExhausted
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = make_batch(cfg, 2, 24, seed=3)
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def _prompt(cfg, n, seed=0):
    return np.asarray(make_batch(cfg, 1, n, seed=seed)["tokens"][0]).tolist()


def _swan(**kw):
    kw.setdefault("k_max", 8)
    kw.setdefault("buffer", 4)
    kw.setdefault("mode", "topk")
    return SwanConfig(**kw)


def _mixed_trace(cfg):
    """Mixed prompt lengths, mixed per-request k, staggered arrivals."""
    spec = [(6, 8, 8, 0), (11, 5, 4, 0), (17, 9, None, 2), (9, 6, 2, 4)]
    return [Request(uid=f"m{i}", tokens=_prompt(cfg, n, seed=20 + i),
                    max_new_tokens=g, k=k, arrival_step=a)
            for i, (n, g, k, a) in enumerate(spec)]


# ---------------------------------------------------------------------------
# Acceptance: paged == slab, token for token
# ---------------------------------------------------------------------------

def test_paged_matches_slab_mixed_length_mixed_k(setup):
    """The acceptance bar: a paged mixed-length, mixed-k Poisson-style run
    (fewer slots than requests -> queueing + backfill into freed slots,
    whose pages were just reclaimed) reproduces the slab engine exactly."""
    cfg, api, params, absorbed, pj = setup
    kw = dict(swan=_swan(), projections=pj, max_seq=64, n_slots=2)
    slab = ServeEngine(cfg, absorbed, **kw)
    want = {c.uid: c.tokens for c in slab.run(_mixed_trace(cfg))}

    paged = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE, **kw)
    got = {c.uid: c.tokens for c in paged.run(_mixed_trace(cfg))}
    assert got == want
    assert paged.pool.live_pages == 0          # drained -> fully reclaimed
    paged.pool.check_consistent()
    # mixed-k still shares one compiled decode executable per page-count
    # bucket (max_seq/PAGE = 4 pages -> buckets {1,2,4}: at most 3)
    assert paged.decode_cache_size == -1 or paged.decode_cache_size <= 3


def test_overcommitted_pool_is_token_identical(setup):
    """A pool smaller than worst case: admissions wait for retirements to
    free pages, and outputs still match the slab engine."""
    cfg, api, params, absorbed, pj = setup
    kw = dict(swan=_swan(), projections=pj, max_seq=64, n_slots=2)
    want = {c.uid: c.tokens for c in
            ServeEngine(cfg, absorbed, **kw).run(_mixed_trace(cfg))}
    # 64/16 = 4 pages/slot worst case; grant only 5 usable pages for 2 slots
    paged = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE,
                        n_pages=6, **kw)
    got = {c.uid: c.tokens for c in paged.run(_mixed_trace(cfg))}
    assert got == want
    rep = paged.cache_report()
    assert rep["reserved_bytes"] < ServeEngine(
        cfg, absorbed, paged=True, page_size=PAGE, **kw
    ).cache_report()["reserved_bytes"]


# ---------------------------------------------------------------------------
# Live-byte accounting
# ---------------------------------------------------------------------------

def test_live_bytes_track_tokens_and_reclaim(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(buffer=2), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=8)
    for r in _mixed_trace(cfg):
        eng.submit(r)
    live, retired_at = [], []
    while not eng.done:
        n_ret = eng.step()
        live.append(eng.cache_report()["live_bytes"])
        if n_ret:
            retired_at.append(len(live) - 1)
    rep = eng.cache_report()
    # grows with generated tokens, stays under slab residency, reclaims
    assert any(b2 > b1 for b1, b2 in zip(live, live[1:]))
    assert max(live) < rep["slab_bytes"]
    assert min(live[retired_at[0]:]) < max(live)
    assert rep["live_pages"] == 0
    assert rep["live_bytes"] < rep["reserved_bytes"]


def test_cache_report_counts_shipped_table_prefix(setup):
    """Device overhead must count the page-table prefix actually SHIPPED
    per decode step ([n_slots, p_bucket] int32), not the host-resident
    numpy table."""
    from repro.core import paged_cache as pc
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=PAGE)
    eng.submit(Request(uid="x", tokens=_prompt(cfg, 20), max_new_tokens=8))
    for _ in range(4):
        eng.step()
    rep = eng.cache_report()
    page_b = pc.page_bytes(cfg, eng.swan, PAGE)
    overhead = rep["live_bytes"] - eng.pool.live_bytes(page_b)
    assert overhead == (pc.ring_bytes(cfg, eng.swan, eng.n_slots)
                        + eng.page_table_shipped_bytes())
    # one live sequence on 2 of 4 logical pages: the shipped prefix is a
    # strict subset of the full host table
    assert eng.page_table_shipped_bytes() < eng.pool.table.nbytes
    assert rep["reserved_bytes"] - eng.pool.reserved_bytes(page_b) == overhead


def test_slab_engine_reserved_equals_live(setup):
    """The slab engine's analytic worst-case layout must coincide with the
    bytes actually resident in its state arrays (asserted inside
    cache_report) — for SWAN and dense engines alike."""
    cfg, api, params, absorbed, pj = setup
    rep = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2).cache_report()
    assert rep["reserved_bytes"] == rep["live_bytes"]
    rep_d = ServeEngine(cfg, params, max_seq=64, n_slots=2).cache_report()
    assert rep_d["reserved_bytes"] == rep_d["live_bytes"]


def test_cache_report_shard_breakdown_sums_to_totals(setup):
    """``shards`` must break reserved/live/shipped-table bytes down
    per mesh shard with entries that sum EXACTLY to the totals (one entry
    on a single device) — for the paged and slab engines alike."""
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=PAGE)
    eng.submit(Request(uid="x", tokens=_prompt(cfg, 20), max_new_tokens=8))
    for _ in range(4):
        eng.step()
    rep = eng.cache_report()
    assert len(rep["shards"]) == 1                       # dp=1
    assert sum(s["reserved_bytes"] for s in rep["shards"]) \
        == rep["reserved_bytes"]
    assert sum(s["live_bytes"] for s in rep["shards"]) == rep["live_bytes"]
    assert sum(s["page_table_shipped_bytes"] for s in rep["shards"]) \
        == eng.page_table_shipped_bytes()
    assert sum(s["live_pages"] for s in rep["shards"]) == rep["live_pages"]
    slab = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                       max_seq=64, n_slots=2).cache_report()
    assert sum(s["reserved_bytes"] for s in slab["shards"]) \
        == slab["reserved_bytes"]
    assert sum(s["live_bytes"] for s in slab["shards"]) == slab["live_bytes"]


# ---------------------------------------------------------------------------
# Pool growth (pool_grow=True): exhaustion -> grow -> drain
# ---------------------------------------------------------------------------

def test_exhausted_pool_grows_and_drains(setup):
    """An over-committed pool that would hold admissions (and raise
    mid-decode) instead GROWS — 2x pages, copy, extended free list — and
    the trace drains token-identically to an uncommitted engine."""
    cfg, api, params, absorbed, pj = setup
    kw = dict(swan=_swan(), projections=pj, max_seq=64, n_slots=2)
    want = {c.uid: c.tokens for c in
            ServeEngine(cfg, absorbed, **kw).run(_mixed_trace(cfg))}
    # 1 usable page = 16 sparse tokens: the long request's lifetime alone
    # overflows it (PagePoolExhausted at admission without pool_grow)
    eng = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE, n_pages=2,
                      pool_grow=True, **kw)
    v0 = eng.pool.version
    got = {c.uid: c.tokens for c in eng.run(_mixed_trace(cfg))}
    assert got == want
    assert eng.pool.n_pages > 2                  # it actually grew
    assert eng.pool.version > v0
    assert eng.pool.live_pages == 0              # drained -> fully reclaimed
    eng.pool.check_consistent()
    # device pool arrays grew in lockstep with the allocator
    assert eng.state["pool"]["k"]["vals"].shape[1] == eng.pool.n_pages


def test_growth_is_capped_at_full_reservation(setup):
    """pool_grow never allocates past the full-reservation cap — at the cap
    every admission fits, so the cap is also the point where growth stops
    being needed."""
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=PAGE,
                      n_pages=4, pool_grow=True, prefill_chunk=16)
    eng.run(_mixed_trace(cfg))
    cap = eng.n_slots * eng.pool.pages_per_seq + 1
    assert eng.pool.pages_per_shard <= cap


# ---------------------------------------------------------------------------
# Failure modes
# ---------------------------------------------------------------------------

def test_never_fitting_request_fails_fast(setup):
    """A request whose lifetime page need exceeds the whole pool raises at
    admission instead of livelocking the queue."""
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=1, paged=True, page_size=PAGE,
                      n_pages=2)    # 1 usable page = 16 sparse tokens
    with pytest.raises(PagePoolExhausted, match="lifetime"):
        eng.run([Request(uid="boom", tokens=_prompt(cfg, 30),
                         max_new_tokens=20)])


def test_mid_decode_exhaustion_raises_cleanly(setup):
    """Two sequences that each fit alone but jointly outgrow an
    over-committed pool exhaust it mid-decode."""
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=PAGE,
                      n_pages=4)    # 3 usable pages; each request peaks at 2
    reqs = [Request(uid=f"g{i}", tokens=_prompt(cfg, 8, seed=i),
                    max_new_tokens=24) for i in range(2)]
    with pytest.raises(PagePoolExhausted):
        eng.run(reqs)
    eng.pool.check_consistent()           # failed alloc corrupted nothing


def test_paged_requires_swan(setup):
    cfg, api, params, absorbed, pj = setup
    with pytest.raises(ValueError, match="SWAN"):
        ServeEngine(cfg, params, max_seq=64, n_slots=1, paged=True)
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                    max_seq=60, n_slots=1, paged=True, page_size=PAGE)


# ---------------------------------------------------------------------------
# Satellites: prompt bucketing + device-side greedy sampling
# ---------------------------------------------------------------------------

def test_bucketing_bounds_prefill_compilations(setup):
    """Six distinct prompt lengths spanning two power-of-two buckets must
    compile at most two prefill executables — and produce exactly the
    tokens an unbucketed engine produces."""
    cfg, api, params, absorbed, pj = setup
    lens = [5, 6, 7, 9, 10, 12]                 # buckets {8, 16}
    reqs = lambda: [Request(uid=f"b{i}", tokens=_prompt(cfg, n, seed=40 + i),
                            max_new_tokens=4)
                    for i, n in enumerate(lens)]
    kw = dict(swan=_swan(), projections=pj, max_seq=64, n_slots=2)
    bucketed = ServeEngine(cfg, absorbed, **kw)
    got = {c.uid: c.tokens for c in bucketed.run(reqs())}
    plain = ServeEngine(cfg, absorbed, bucket_prompts=False, **kw)
    want = {c.uid: c.tokens for c in plain.run(reqs())}
    assert got == want
    if bucketed.prefill_cache_size != -1:       # jit cache introspectable
        assert bucketed.prefill_cache_size <= 2
        assert plain.prefill_cache_size == len(set(lens))


def test_mixed_greedy_and_sampled_matches_slab(setup):
    """Device-side argmax serves the greedy lane while a temperature>0
    request in the same batch still gets host-side sampling — identically
    in paged and slab engines."""
    cfg, api, params, absorbed, pj = setup
    reqs = lambda: [
        Request(uid="greedy", tokens=_prompt(cfg, 9, seed=1), max_new_tokens=6),
        Request(uid="hot", tokens=_prompt(cfg, 7, seed=2), max_new_tokens=6,
                temperature=0.7, seed=13),
    ]
    kw = dict(swan=_swan(), projections=pj, max_seq=64, n_slots=2)
    slab = {c.uid: c.tokens
            for c in ServeEngine(cfg, absorbed, **kw).run(reqs())}
    paged = {c.uid: c.tokens
             for c in ServeEngine(cfg, absorbed, paged=True,
                                  page_size=PAGE, **kw).run(reqs())}
    assert slab == paged
