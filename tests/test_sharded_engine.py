"""Mesh-sharded serve engine: the acceptance bar is that an engine whose
batched state (dense/slab/ring leaves, per-sequence pos/k, and the paged
pool) is sharded over a simulated 8-device host mesh is TOKEN-IDENTICAL to
the single-device engine — for dense/slab/paged caches, mixed per-request
k, temperature lanes, and concurrent chunked prefill — while still issuing
one chunk dispatch + one decode dispatch per engine step.

Multiple devices only exist in a subprocess that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax (the pattern test_sharding.py uses); the in-process tests cover the
host-side topology validation that needs no devices."""
import json
import subprocess
import sys

import pytest

from repro.configs import get_smoke_config
from repro.runtime.page_pool import PagePool

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys
sys.path.insert(0, "src")
import json
import jax
import numpy as np
from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.launch.mesh import make_serve_mesh
from repro.models import get_model
from repro.obs import EventTrace
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

cfg = get_smoke_config("llama3-8b").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, dtype="float32", param_dtype="float32")
api = get_model(cfg)
params = api.init_params(jax.random.PRNGKey(0), cfg)
pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
absorbed = api.absorb(params, cfg, pj)
swan = SwanConfig(k_max=8, buffer=4, mode="topk")
mesh = make_serve_mesh(8)
assert jax.device_count() == 8


def prompt(n, seed):
    return [int(t) for t in make_batch(cfg, 1, n, seed=seed)["tokens"][0]]


def trace(with_k=True):
    # mixed prompt lengths, mixed per-request k (SWAN engines only), a
    # temperature lane, and staggered (Poisson-style) arrivals — every
    # serve feature at once
    spec = [(6, 6, 8, 0.0, 0), (11, 5, 4, 0.0, 0), (17, 7, None, 0.0, 1),
            (9, 6, 2, 0.8, 2), (21, 4, 8, 0.0, 3), (7, 5, 4, 0.0, 4),
            (13, 6, None, 0.0, 4), (5, 4, 8, 0.0, 6)]
    return [Request(uid=f"m{i}", tokens=prompt(n, 20 + i), max_new_tokens=g,
                    k=k if with_k else None, temperature=t, seed=7 + i,
                    arrival_step=a)
            for i, (n, g, k, t, a) in enumerate(spec)]


def drain(eng):
    reqs = trace(with_k=eng.swan is not None)
    for r in reqs:
        eng.submit(r)
    per_step = []
    while not eng.done:
        before = dict(eng.dispatches)
        eng.step()
        per_step.append({k: eng.dispatches[k] - before[k]
                         for k in eng.dispatches})
    return {c.uid: c.tokens for c in eng.completions}, per_step


out = {}
# concurrent chunked prefill on all three cache modes; n_slots=16 over
# dp=8 -> 2 slots per shard
kw = dict(max_seq=64, n_slots=16, prefill_chunk=8, prefill_slots=4)
for mode in ("dense", "slab", "paged"):
    ekw = dict(kw)
    p = params
    if mode != "dense":
        ekw.update(swan=swan, projections=pj)
        p = absorbed
    if mode == "paged":
        ekw.update(paged=True, page_size=8)
    want, _ = drain(ServeEngine(cfg, p, **ekw))
    eng = ServeEngine(cfg, p, mesh=mesh, **ekw)
    got, per_step = drain(eng)
    out[mode] = {
        "identical": got == want,
        "max_chunk_per_step": max(s["chunk"] for s in per_step),
        "max_decode_per_step": max(s["decode"] for s in per_step),
        "dp": eng.dp, "n_local": eng.n_local,
    }
    if mode == "paged":
        rep = eng.cache_report()
        out["paged_report"] = {
            "n_shards": len(rep["shards"]),
            "reserved_sum_ok": sum(s["reserved_bytes"]
                                   for s in rep["shards"])
            == rep["reserved_bytes"],
            "live_sum_ok": sum(s["live_bytes"] for s in rep["shards"])
            == rep["live_bytes"],
            "table_sum_ok": sum(s["page_table_shipped_bytes"]
                                for s in rep["shards"])
            == eng.page_table_shipped_bytes(),
            "drained": eng.pool.live_pages == 0,
        }
        eng.pool.check_consistent()

# monolithic admission (no chunking) stays shardable too
kw_m = dict(max_seq=64, n_slots=8, swan=swan, projections=pj)
want, _ = drain(ServeEngine(cfg, absorbed, **kw_m))
got, _ = drain(ServeEngine(cfg, absorbed, mesh=mesh, **kw_m))
out["monolithic_identical"] = got == want

# pool growth under the mesh: a deliberately tiny per-shard pool grows
# (2x pages, copy, extend free lists) instead of holding admissions
tr = EventTrace()
eng = ServeEngine(cfg, absorbed, mesh=mesh, paged=True, page_size=8,
                  n_pages=16, pool_grow=True, max_seq=64, n_slots=8,
                  swan=swan, projections=pj, prefill_chunk=8,
                  prefill_slots=2, trace=tr)
got, _ = drain(eng)
want, _ = drain(ServeEngine(cfg, absorbed, max_seq=64, n_slots=8,
                            swan=swan, projections=pj, prefill_chunk=8,
                            prefill_slots=2))
eng.pool.check_consistent()
out["grow_sharded"] = {"identical": got == want,
                       "grew": eng.pool.pages_per_shard > 2}
# latency accounting survives sharded concurrent prefill: exactly one
# first_token event per request, agreeing with the Completion fields
ft = {c.uid: [e for e in tr.select("first_token", uid=c.uid)]
      for c in eng.completions}
out["obs_sharded"] = {
    "first_token_once": all(len(v) == 1 for v in ft.values()),
    "first_token_steps_match": all(
        ft[c.uid][0]["step"] == c.first_token_step
        for c in eng.completions if ft[c.uid]),
    "ttft_count": eng.metrics.get("serve_ttft_steps").count,
    "n_completions": len(eng.completions),
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def shard_run():
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("mode", ["dense", "slab", "paged"])
def test_sharded_engine_token_identical(shard_run, mode):
    """8-way sharded == single-device, token for token, with concurrent
    chunked prefill, mixed per-request k and a temperature lane."""
    rec = shard_run[mode]
    assert rec["dp"] == 8 and rec["n_local"] == 2
    assert rec["identical"], f"{mode} engine diverged under sharding"


@pytest.mark.parametrize("mode", ["dense", "slab", "paged"])
def test_one_dispatch_per_step_regardless_of_shards(shard_run, mode):
    """Each engine step issues at most ONE packed chunk dispatch and ONE
    decode dispatch — per-step dispatch count is independent of shard
    count (the host never loops over shards)."""
    rec = shard_run[mode]
    assert rec["max_chunk_per_step"] <= 1
    assert rec["max_decode_per_step"] <= 1


def test_sharded_monolithic_admission(shard_run):
    assert shard_run["monolithic_identical"]


def test_sharded_cache_report_shards_sum(shard_run):
    rep = shard_run["paged_report"]
    assert rep["n_shards"] == 8
    assert rep["reserved_sum_ok"] and rep["live_sum_ok"]
    assert rep["table_sum_ok"]
    assert rep["drained"]


def test_sharded_pool_growth(shard_run):
    rec = shard_run["grow_sharded"]
    assert rec["identical"] and rec["grew"]


def test_sharded_first_token_recorded_exactly_once(shard_run):
    """Completion.first_token_step accounting holds under sharded
    concurrent chunked prefill: one first_token trace event per request,
    at the step the completion records, and one TTFT observation each."""
    rec = shard_run["obs_sharded"]
    assert rec["first_token_once"]
    assert rec["first_token_steps_match"]
    assert rec["ttft_count"] == rec["n_completions"] == 8


# ---------------------------------------------------------------------------
# Host-side topology validation (no devices needed)
# ---------------------------------------------------------------------------

def test_pool_shard_locality():
    """Slots only ever map pages from their own shard's block, and the
    per-shard free lists never cross."""
    pool = PagePool(8, 4, 4, 8, n_shards=2)     # 3 usable pages per shard
    pool.ensure(0, 24)                          # slot 0 -> shard 0
    pool.ensure(2, 24)                          # slot 2 -> shard 1
    assert pool.shard_of(0) == 0 and pool.shard_of(2) == 1
    # local indices: both slots can hold the SAME local page numbers
    assert set(pool.table[0, :3]) == set(pool.table[2, :3])
    assert pool.shard_free_pages(0) == 0 and pool.shard_free_pages(1) == 0
    assert pool.live_pages == 6
    pool.check_consistent()
    pool.free_slot(0)
    assert pool.shard_free_pages(0) == 3 and pool.shard_free_pages(1) == 0
    pool.check_consistent()


def test_state_specs_are_data_only_on_mixed_meshes():
    """A mesh that also carries a 'model' axis must NOT shard cache
    sequence dims over it: the serve dispatch bodies are lane-local (no
    split-S stat merge), so every non-data axis is stripped from the
    engine's shard_map specs — sharding a sequence dim there would
    silently corrupt the softmax."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh
    from repro.models import get_model
    from repro.runtime.serve_engine import Request, ServeEngine

    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((1, 1), ("data", "model"))      # fits one device
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=2, mesh=mesh)
    axes = {ax for spec in jax.tree_util.tree_leaves(
                eng._state_specs, is_leaf=lambda x: isinstance(x, P))
            for ax in tuple(spec) if ax is not None}
    assert "model" not in axes and axes <= {"data", ("data",)}
    # and the engine still decodes on such a mesh
    got = eng.run([Request(uid="x",
                           tokens=[1, 2, 3, 4, 5], max_new_tokens=3)])
    want = ServeEngine(cfg, params, max_seq=64, n_slots=2).run(
        [Request(uid="x", tokens=[1, 2, 3, 4, 5], max_new_tokens=3)])
    assert got[0].tokens == want[0].tokens


def test_engine_rejects_indivisible_mesh():
    cfg = get_smoke_config("llama3-8b")

    class FakeMesh:
        axis_names = ("data",)
        shape = {"data": 3}

    from repro.runtime.serve_engine import ServeEngine
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(cfg, {}, max_seq=64, n_slots=4, mesh=FakeMesh())


def test_engine_rejects_meshes_without_data_axis():
    cfg = get_smoke_config("llama3-8b")

    class FakeMesh:
        axis_names = ("model",)
        shape = {"model": 2}

    from repro.runtime.serve_engine import ServeEngine
    with pytest.raises(ValueError, match="data"):
        ServeEngine(cfg, {}, max_seq=64, n_slots=4, mesh=FakeMesh())
