"""repro.obs unit tests: instrument semantics, exporter round-trips and
the schema-drift guard (every registered series must survive the JSON
snapshot round-trip AND appear in the Prometheus text exposition)."""
import json
import math

import pytest

from repro.obs import (EventTrace, MetricsRegistry, NULL_REGISTRY,
                       NullRegistry, StepProfiler, parse_prometheus, span)


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc()
    c.inc(3)
    assert reg.value("reqs_total") == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.dec(2)
    g.inc()
    assert reg.value("depth") == 6
    # missing series reads the default, never registers
    assert reg.value("nope", default=-1) == -1
    assert reg.get("nope") is None


def test_labels_are_distinct_series():
    reg = MetricsRegistry()
    reg.counter("dispatches", "by kind", kind="chunk").inc(2)
    reg.counter("dispatches", "by kind", kind="decode").inc(5)
    assert reg.value("dispatches", kind="chunk") == 2
    assert reg.value("dispatches", kind="decode") == 5
    # idempotent getter: same (name, labels) -> same instrument
    assert reg.counter("dispatches", kind="chunk") is \
        reg.counter("dispatches", kind="chunk")


def test_kind_and_bucket_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("x", "c")
    with pytest.raises(ValueError):
        reg.gauge("x")
    reg.histogram("h", (1, 2, 4))
    with pytest.raises(ValueError):
        reg.histogram("h", (1, 2, 8))
    with pytest.raises(ValueError):
        reg.histogram("bad", ())
    with pytest.raises(ValueError):
        reg.histogram("bad", (4, 2, 1))


def test_histogram_observe_quantile_mean():
    reg = MetricsRegistry()
    h = reg.histogram("ttft", (1, 2, 4, 8), "steps")
    for v in (1, 1, 3, 5, 100):        # 100 -> overflow bucket
        h.observe(v)
    assert h.count == 5
    assert h.sum == 110
    assert h.counts == [2, 0, 1, 1, 1]
    assert h.quantile(0.0) == 1
    assert h.quantile(0.4) == 1        # rank 2 lands in the first bucket
    assert h.quantile(0.5) == 4        # rank 2.5 -> 3rd observation, le=4
    assert h.quantile(1.0) == math.inf
    assert h.mean == 22
    empty = reg.histogram("empty", (1,))
    assert math.isnan(empty.quantile(0.5))
    assert math.isnan(empty.mean)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_value_raises():
    reg = MetricsRegistry()
    reg.histogram("h", (1, 2)).observe(1)
    with pytest.raises(TypeError):
        reg.value("h")


# ---------------------------------------------------------------------------
# Exporters — the schema-drift guard
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("serve_requests_total", "submitted").inc(12)
    reg.counter("serve_dispatches_total", "by kind", kind="chunk").inc(4)
    reg.counter("serve_dispatches_total", "by kind", kind="decode").inc(9)
    reg.gauge("queue_depth", "pending").set(3)
    reg.gauge("shard_lanes", "by shard", shard=0).set(2)
    reg.gauge("shard_lanes", "by shard", shard=1).set(1)
    h = reg.histogram("ttft_steps", (1, 2, 4, 8), "ttft")
    for v in (1, 3, 3, 9):
        h.observe(v)
    reg.histogram("wall_ms", (0.5, 2.0), "span").observe(0.75)
    reg._family("registered_but_empty", "counter", "no series yet", None)
    return reg


def test_json_snapshot_round_trip_exact():
    reg = _populated_registry()
    snap = reg.snapshot()
    # snapshot is pure JSON (no tuples/sets leak through)
    snap2 = json.loads(reg.to_json())
    assert snap2 == snap
    back = MetricsRegistry.from_snapshot(snap)
    assert back.snapshot() == snap
    # values really came back, not just structure
    assert back.value("serve_dispatches_total", kind="decode") == 9
    h = back.get("ttft_steps")
    assert (h.counts, h.sum, h.count) == ([1, 0, 2, 0, 1], 16.0, 4)
    # zero-series families survive too (schema, not just data)
    assert "registered_but_empty" in back.names()


def test_prometheus_contains_every_registered_series():
    reg = _populated_registry()
    parsed = parse_prometheus(reg.to_prometheus())
    for name in reg.names():
        fam = reg._families[name]
        assert parsed["types"].get(name) == fam["kind"], name
        for key, inst in fam["series"].items():
            if fam["kind"] == "histogram":
                labels = dict(key)
                assert parsed["samples"][
                    (f"{name}_count", tuple(sorted(labels.items())))] \
                    == inst.count
                assert parsed["samples"][
                    (f"{name}_sum", tuple(sorted(labels.items())))] \
                    == inst.sum
                # +Inf bucket is cumulative == count
                inf_key = tuple(sorted({**labels, "le": "+Inf"}.items()))
                assert parsed["samples"][(f"{name}_bucket", inf_key)] \
                    == inst.count
            else:
                assert parsed["samples"][(name, key)] == inst.value, name


def test_prometheus_histogram_buckets_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", (1, 2, 4), "l")
    for v in (1, 2, 2, 3, 99):
        h.observe(v)
    text = reg.to_prometheus()
    assert 'lat_bucket{le="1"} 1' in text
    assert 'lat_bucket{le="2"} 3' in text
    assert 'lat_bucket{le="4"} 4' in text
    assert 'lat_bucket{le="+Inf"} 5' in text
    assert "lat_sum 107" in text
    assert "lat_count 5" in text


# ---------------------------------------------------------------------------
# Null registry
# ---------------------------------------------------------------------------

def test_null_registry_is_inert():
    null = NullRegistry()
    assert not null.enabled
    assert NULL_REGISTRY.enabled is False
    c = null.counter("x", "h", kind="a")
    c.inc(5)
    g = null.gauge("y")
    g.set(3)
    h = null.histogram("z", (1, 2))
    h.observe(9)
    assert math.isnan(h.quantile(0.5))
    assert null.get("x") is None
    assert null.snapshot() == {"metrics": {}}
    assert null.to_prometheus().strip() == ""
    # the same shared instrument absorbs everything — no state anywhere
    assert c.value == 0 and h.count == 0


# ---------------------------------------------------------------------------
# Event trace, spans, profiler hook
# ---------------------------------------------------------------------------

def test_event_trace_memory_and_select():
    tr = EventTrace()
    tr.emit("admit", step=3, uid="r0", slot=1)
    tr.emit("admit", step=4, uid="r1", slot=0)
    tr.emit("retire", step=9, uid="r0", slot=1)
    assert [e["uid"] for e in tr.select("admit")] == ["r0", "r1"]
    assert tr.select("admit", uid="r1")[0]["step"] == 4
    assert tr.select("nope") == []


def test_event_trace_file_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    with EventTrace(path) as tr:
        tr.emit("submit", step=0, uid="a", prompt_len=7)
        tr.emit("token", step=2, uid="a", index=0, token=42)
        assert tr.events == []            # keep defaults to False with path
    back = EventTrace.read(path)
    assert back == [
        {"event": "submit", "step": 0, "uid": "a", "prompt_len": 7},
        {"event": "token", "step": 2, "uid": "a", "index": 0, "token": 42},
    ]


def test_span_emits_wall_ms_and_none_is_noop():
    with span(None, "nothing"):
        pass                              # must not raise
    tr = EventTrace()
    with span(tr, "prefill", step=5, uid="r0"):
        pass
    (ev,) = tr.select("span")
    assert ev["name"] == "prefill" and ev["uid"] == "r0" and ev["step"] == 5
    assert ev["wall_ms"] >= 0.0


def test_step_profiler_brackets_exactly_n_steps():
    calls = []
    tr = EventTrace()
    prof = StepProfiler("/tmp/prof", 3, trace=tr,
                        start=lambda d: calls.append(("start", d)),
                        stop=lambda: calls.append(("stop",)))
    for step in range(10):
        prof.step_start(step)
        prof.step_end(step + 1)
    assert calls == [("start", "/tmp/prof"), ("stop",)]
    assert prof.done and not prof.active
    assert tr.select("profile_start")[0]["n_steps"] == 3
    assert tr.select("profile_stop")[0]["step"] == 3
    with pytest.raises(ValueError):
        StepProfiler("/tmp/prof", 0)
