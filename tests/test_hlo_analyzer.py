"""Loop-aware HLO analyzer: trip-count multiplication, collective parsing,
dot-flop counting from shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze_hlo, parse_instr_line, parse_module


def test_dot_flops_from_shapes():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analyze_hlo(jax.jit(f).lower(a, b).compile().as_text())
    expect = 2 * 64 * 128 * 32
    assert abs(c.flops - expect) / expect < 0.05


def test_scan_trip_count_multiplied():
    def one(x, w):
        return jnp.sum(x @ w)

    def scanned(x, ws):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w1 = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    wn = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c1 = analyze_hlo(jax.jit(one).lower(x, w1).compile().as_text())
    cn = analyze_hlo(jax.jit(scanned).lower(x, wn).compile().as_text())
    ratio = cn.flops / c1.flops
    assert 10 <= ratio <= 14, ratio


def test_instr_parser_handles_tuple_types_with_comments():
    line = ('  %while.5 = (s32[], bf16[8,1,2048]{2,1,0}, /*index=2*/'
            'f32[16,2048]{1,0}) while(%tuple.1), condition=%cond, '
            'body=%body, backend_config={"known_trip_count":{"n":"16"}}')
    ins = parse_instr_line(line)
    assert ins is not None
    assert ins.opcode == "while"
    assert "known_trip_count" in ins.attrs


def test_parse_module_roundtrip():
    def f(x):
        return jnp.tanh(x).sum()
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comps, entry = parse_module(jax.jit(f).lower(x).compile().as_text())
    assert entry is not None
    assert comps[entry].instrs
