"""Loop-aware HLO analyzer: trip-count multiplication, collective parsing,
dot-flop counting from shapes, host-transfer census, async collective
pairing, sub-byte dtype sizing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import (_shape_info, analyze_hlo, parse_instr_line,
                                parse_module, transfer_stats)


def test_dot_flops_from_shapes():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analyze_hlo(jax.jit(f).lower(a, b).compile().as_text())
    expect = 2 * 64 * 128 * 32
    assert abs(c.flops - expect) / expect < 0.05


def test_scan_trip_count_multiplied():
    def one(x, w):
        return jnp.sum(x @ w)

    def scanned(x, ws):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w1 = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    wn = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c1 = analyze_hlo(jax.jit(one).lower(x, w1).compile().as_text())
    cn = analyze_hlo(jax.jit(scanned).lower(x, wn).compile().as_text())
    ratio = cn.flops / c1.flops
    assert 10 <= ratio <= 14, ratio


def test_instr_parser_handles_tuple_types_with_comments():
    line = ('  %while.5 = (s32[], bf16[8,1,2048]{2,1,0}, /*index=2*/'
            'f32[16,2048]{1,0}) while(%tuple.1), condition=%cond, '
            'body=%body, backend_config={"known_trip_count":{"n":"16"}}')
    ins = parse_instr_line(line)
    assert ins is not None
    assert ins.opcode == "while"
    assert "known_trip_count" in ins.attrs


def test_parse_module_roundtrip():
    def f(x):
        return jnp.tanh(x).sum()
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comps, entry = parse_module(jax.jit(f).lower(x).compile().as_text())
    assert entry is not None
    assert comps[entry].instrs


# ---------------------------------------------------------------------------
# Sub-byte / f8 dtype sizing
# ---------------------------------------------------------------------------

def test_sub_byte_dtypes_sized_in_bits():
    # s4 packs two elements per byte: round AFTER the element product
    assert _shape_info("s4[4096,128]") == (4096 * 128 // 2, 4096 * 128)
    assert _shape_info("u4[3]") == (2, 3)            # 12 bits -> 2 bytes
    assert _shape_info("f8e4m3fn[16]") == (16, 16)
    assert _shape_info("f8e5m2fnuz[8,8]") == (64, 64)


def test_sub_byte_shapes_through_instr_parser():
    ins = parse_instr_line(
        "  %q = s4[64,128]{1,0} convert(%p0)")
    assert ins is not None and ins.bytes == 64 * 128 // 2


def test_sub_byte_end_to_end_via_jit():
    def f(x):
        return (x.astype(jnp.int4).astype(jnp.int8)).sum()
    x = jax.ShapeDtypeStruct((128, 128), jnp.int8)
    txt = jax.jit(f).lower(x).compile().as_text()
    c = analyze_hlo(txt)
    assert c.flops >= 0 and c.hbm_bytes > 0          # parses end-to-end


# ---------------------------------------------------------------------------
# Async collectives: -start/-done pairs count exactly once
# ---------------------------------------------------------------------------

_ASYNC_HLO = """\
HloModule async

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128] parameter(0)
  %ar-start = (f32[8,128], f32[8,128]) all-reduce-start(%p0), replica_groups={}
  %ar-done = f32[8,128] all-reduce-done(%ar-start)
  ROOT %out = f32[8,128] add(%ar-done, %p0)
}
"""


def test_async_collective_counted_once_with_result_bytes():
    c = analyze_hlo(_ASYNC_HLO)
    assert c.collective_count == 1
    # result tuple component only — NOT the (operand, result) pair
    assert c.collective_bytes == 8 * 128 * 4
    assert c.per_collective == {"all-reduce": 8 * 128 * 4}


def test_transfer_stats_pairs_and_unmatched():
    ts = transfer_stats(_ASYNC_HLO)
    assert ts.collective_starts == 1 and ts.collective_dones == 1
    assert ts.unmatched_async == 0 and ts.host_total == 0
    dangling = _ASYNC_HLO.replace(
        "  %ar-done = f32[8,128] all-reduce-done(%ar-start)\n", "").replace(
        "add(%ar-done, %p0)", "add(%p0, %p0)")
    ts2 = transfer_stats(dangling)
    assert ts2.collective_starts == 1 and ts2.collective_dones == 0
    assert ts2.unmatched_async == 1


# ---------------------------------------------------------------------------
# Host-transfer census
# ---------------------------------------------------------------------------

def test_transfer_stats_counts_each_boundary_kind_once():
    hlo = """\
HloModule transfers

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %tok = token[] after-all()
  %inf = ((f32[4]), token[]) infeed(%tok)
  %outf = token[] outfeed(%p0, %tok)
  %snd = (f32[4], u32[], token[]) send(%p0, %tok), channel_id=1, is_host_transfer=true
  %snd-done = token[] send-done(%snd), channel_id=1, is_host_transfer=true
  %rcv = (f32[4], u32[], token[]) recv(%tok), channel_id=2, is_host_transfer=true
  %rcv-done = (f32[4], token[]) recv-done(%rcv), channel_id=2, is_host_transfer=true
  %hcp = f32[4]{0:S(5)} copy(%p0)
  %mth = f32[4] custom-call(%p0), custom_call_target="MoveToHost"
  ROOT %out = f32[4] add(%p0, %p0)
}
"""
    ts = transfer_stats(hlo)
    assert ts.infeed == 1 and ts.outfeed == 1
    assert ts.host_send == 1 and ts.host_recv == 1     # -done not recounted
    assert ts.host_copy == 1 and ts.move_custom_calls == 1
    assert ts.host_total == 6
    c = analyze_hlo(hlo)
    assert c.host_transfers == 6


def test_transfer_stats_ignores_device_traffic():
    hlo = """\
HloModule clean

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %cp = f32[4]{0} copy(%p0)
  %tok = token[] after-all()
  %snd = (f32[4], u32[], token[]) send(%cp, %tok), channel_id=3
  ROOT %out = f32[4] add(%cp, %p0)
}
"""
    ts = transfer_stats(hlo)
    assert ts.host_total == 0          # device copy + device send don't count


def test_jitted_fn_has_no_host_transfers():
    def f(x):
        return jnp.tanh(x) @ x
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ts = transfer_stats(jax.jit(f).lower(x).compile().as_text())
    assert ts.host_total == 0 and ts.unmatched_async == 0
