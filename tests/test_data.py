"""Data pipeline: determinism, host-disjointness, file-backed stream."""
import os

import numpy as np

from repro.data.pipeline import FileStream, SyntheticStream, write_token_file


def test_synthetic_deterministic():
    s1 = SyntheticStream(256, 4, 16, seed=7)
    s2 = SyntheticStream(256, 4, 16, seed=7)
    b1, b2 = s1.batch_at(12), s2.batch_at(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = s1.batch_at(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_synthetic_host_disjoint():
    a = SyntheticStream(256, 4, 16, seed=0, host_id=0, n_hosts=2).batch_at(5)
    b = SyntheticStream(256, 4, 16, seed=0, host_id=1, n_hosts=2).batch_at(5)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_synthetic_has_learnable_structure():
    s = SyntheticStream(64, 8, 96, seed=0)
    toks = s.batch_at(0)["tokens"]
    follow = s._next_tok[toks[:, :-1]]
    frac_markov = (follow == toks[:, 1:]).mean()
    assert frac_markov > 0.4   # ~0.5 by construction
    # long-range copy at the configured period
    P = s.copy_period
    frac_copy = (toks[:, P:] == toks[:, :-P]).mean()
    assert frac_copy > 0.3


def test_file_stream(tmp_path):
    path = str(tmp_path / "tokens.bin")
    rng = np.random.default_rng(0)
    write_token_file(path, rng.integers(0, 1000, 16 * 17))
    fs = FileStream(path, vocab_size=1000, batch=4, seq=16, seed=0)
    b0 = fs.batch_at(0)
    assert b0["tokens"].shape == (4, 17)
    assert b0["tokens"].max() < 1000
    np.testing.assert_array_equal(b0["tokens"], fs.batch_at(0)["tokens"])
    # different hosts read different rows
    fs2 = FileStream(path, vocab_size=1000, batch=4, seq=16, seed=0,
                     host_id=1, n_hosts=2)
    assert not np.array_equal(b0["tokens"], fs2.batch_at(0)["tokens"])


def test_file_stream_prefetch(tmp_path):
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, np.arange(8 * 9) % 500)
    fs = FileStream(path, vocab_size=500, batch=2, seq=8, seed=0)
    it = fs.prefetching_iter(0)
    a = next(it)
    np.testing.assert_array_equal(a["tokens"], fs.batch_at(0)["tokens"])
