"""Continuous-batching ServeEngine: admission/retirement ordering, per-request
SWAN k overrides (one compiled decode executable for mixed-k batches), and
mixed-length batches matching single-sequence decoding exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Completion, Request, ServeEngine
from repro.runtime.serve_loop import ServeSession, calibrate_swan


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = make_batch(cfg, 2, 24, seed=3)
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def _prompt(cfg, n, seed=0):
    return np.asarray(make_batch(cfg, 1, n, seed=seed)["tokens"][0]).tolist()


def _swan(cfg, **kw):
    kw.setdefault("k_max", cfg.d_head)
    kw.setdefault("buffer", 4)
    kw.setdefault("mode", "topk")
    return SwanConfig(**kw)


# ---------------------------------------------------------------------------
# Scheduling
# ---------------------------------------------------------------------------

def test_admission_retirement_ordering(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=2)
    reqs = [Request(uid=f"r{i}", tokens=_prompt(cfg, 8, seed=i),
                    max_new_tokens=n)
            for i, n in enumerate([3, 6, 4, 2])]
    comps = eng.run(reqs)
    assert eng.done
    assert [c.uid for c in comps] == sorted([c.uid for c in comps],
                                            key=lambda u: [c.finished_step
                                                           for c in comps
                                                           if c.uid == u][0])
    by_uid = {c.uid: c for c in comps}
    assert set(by_uid) == {"r0", "r1", "r2", "r3"}
    for i, n in enumerate([3, 6, 4, 2]):
        assert len(by_uid[f"r{i}"].tokens) == n
    # only 2 slots: r0/r1 admitted immediately, r2/r3 had to wait for a
    # retirement; the shortest request (r0) finishes first
    assert by_uid["r0"].admitted_step == 0
    assert by_uid["r1"].admitted_step == 0
    assert by_uid["r2"].admitted_step > 0
    assert by_uid["r3"].admitted_step > 0
    assert comps[0].uid == "r0"
    # a freed slot is backfilled: r2 starts no later than the step after r0 ends
    assert by_uid["r2"].admitted_step <= by_uid["r0"].finished_step + 1


def test_arrival_steps_delay_admission(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=2)
    comps = eng.run([
        Request(uid="now", tokens=_prompt(cfg, 6), max_new_tokens=2),
        Request(uid="later", tokens=_prompt(cfg, 6, seed=1),
                max_new_tokens=2, arrival_step=5),
    ])
    by_uid = {c.uid: c for c in comps}
    assert by_uid["now"].admitted_step == 0
    assert by_uid["later"].admitted_step >= 5


def test_eos_retires_early(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=1)
    # find the greedy second token, then use it as eos for a re-run
    probe = eng.run([Request(uid="p", tokens=_prompt(cfg, 8),
                             max_new_tokens=4)])[0]
    eos = probe.tokens[1]
    eng2 = ServeEngine(cfg, params, max_seq=64, n_slots=1)
    out = eng2.run([Request(uid="e", tokens=_prompt(cfg, 8),
                            max_new_tokens=16, eos=eos)])[0]
    assert out.tokens[-1] == eos
    # retires at the FIRST greedy occurrence of eos (inclusive)
    assert len(out.tokens) == probe.tokens.index(eos) + 1


# ---------------------------------------------------------------------------
# Per-request k (runtime-tunable compression)
# ---------------------------------------------------------------------------

def test_mixed_k_single_decode_executable(setup):
    cfg, api, params, absorbed, pj = setup
    swan = _swan(cfg)
    eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                      max_seq=64, n_slots=3)
    reqs = [Request(uid=f"k{k}", tokens=_prompt(cfg, 16, seed=9),
                    max_new_tokens=6, k=k)
            for k in [cfg.d_head, cfg.d_head // 2, cfg.d_head // 4]]
    comps = eng.run(reqs)
    assert len(comps) == 3
    # the paper's runtime tunability: mixed compression levels in one batch,
    # k is a traced operand — exactly one compiled decode executable
    # (-1 = this jax build exposes no jit cache introspection)
    assert eng.decode_cache_size in (1, -1)
    # compression must actually bite: full-k and quarter-k outputs diverge
    by_uid = {c.uid: c.tokens for c in comps}
    assert by_uid[f"k{cfg.d_head}"] != by_uid[f"k{cfg.d_head // 4}"]


def test_full_k_request_matches_dense_session(setup):
    """A k=d_head request through the engine reproduces dense greedy decoding
    (SWAN at full retention is exact)."""
    cfg, api, params, absorbed, pj = setup
    prompt = _prompt(cfg, 12, seed=4)
    sess = ServeSession(cfg, params, max_seq=64, batch=1)
    want = np.asarray(sess.generate(
        {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 8))[0].tolist()
    eng = ServeEngine(cfg, absorbed, swan=_swan(cfg), projections=pj,
                      max_seq=64, n_slots=1)
    got = eng.run([Request(uid="x", tokens=prompt, max_new_tokens=8,
                           k=cfg.d_head)])[0].tokens
    assert got == want


def test_request_k_validation(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(cfg, k_max=8),
                      projections=pj, max_seq=64, n_slots=1)
    with pytest.raises(ValueError, match="k_max"):
        eng.submit(Request(uid="big", tokens=_prompt(cfg, 8),
                           max_new_tokens=2, k=16))
    dense = ServeEngine(cfg, params, max_seq=64, n_slots=1)
    with pytest.raises(ValueError, match="SWAN"):
        dense.submit(Request(uid="nok", tokens=_prompt(cfg, 8),
                             max_new_tokens=2, k=4))


# ---------------------------------------------------------------------------
# Mixed-length correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_swan", [False, True])
def test_mixed_length_matches_single_sequence(setup, use_swan):
    """A mixed-length continuous batch must produce, per request, exactly the
    tokens that request gets when decoded alone (per-sequence positions and
    ring masks keep lanes independent)."""
    cfg, api, params, absorbed, pj = setup
    swan = _swan(cfg, k_max=8, buffer=4) if use_swan else None
    p = absorbed if use_swan else params
    kw = dict(swan=swan, projections=pj if use_swan else None, max_seq=64)
    reqs = [Request(uid=f"m{i}", tokens=_prompt(cfg, n, seed=20 + i),
                    max_new_tokens=g)
            for i, (n, g) in enumerate([(6, 8), (11, 5), (17, 9)])]

    eng = ServeEngine(cfg, p, n_slots=3, **kw)
    batched = {c.uid: c.tokens for c in eng.run(reqs)}

    for r in reqs:
        solo_eng = ServeEngine(cfg, p, n_slots=1, **kw)
        solo = solo_eng.run([Request(uid=r.uid, tokens=r.tokens,
                                     max_new_tokens=r.max_new_tokens)])
        assert batched[r.uid] == solo[0].tokens, r.uid


def test_backfill_mid_flight_matches_single(setup):
    """A request admitted into a just-freed slot (dirty cache from the
    previous occupant) must decode identically to a fresh engine."""
    cfg, api, params, absorbed, pj = setup
    swan = _swan(cfg, k_max=8, buffer=4)
    kw = dict(swan=swan, projections=pj, max_seq=64)
    eng = ServeEngine(cfg, absorbed, n_slots=1, **kw)
    comps = eng.run([
        Request(uid="first", tokens=_prompt(cfg, 9, seed=1), max_new_tokens=6),
        Request(uid="second", tokens=_prompt(cfg, 13, seed=2), max_new_tokens=7),
    ])
    solo = ServeEngine(cfg, absorbed, n_slots=1, **kw).run(
        [Request(uid="second", tokens=_prompt(cfg, 13, seed=2),
                 max_new_tokens=7)])
    by_uid = {c.uid: c for c in comps}
    assert by_uid["second"].admitted_step > 0          # really backfilled
    assert by_uid["second"].tokens == solo[0].tokens


def test_engine_temperature_path(setup):
    """The engine's temperature sampling (host-side, per-request keys) —
    previously untested: deterministic per seed across runs, different
    across seeds, and a mixed greedy/temperature batch still compiles
    exactly one decode executable (temperature only changes what the host
    does with the logits)."""
    cfg, api, params, absorbed, pj = setup
    reqs = lambda seed=13: [
        Request(uid="greedy", tokens=_prompt(cfg, 9, seed=1), max_new_tokens=6),
        Request(uid="hot", tokens=_prompt(cfg, 7, seed=2), max_new_tokens=6,
                temperature=0.8, seed=seed),
    ]
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=2)
    a = {c.uid: c.tokens for c in eng.run(reqs())}
    assert eng.decode_cache_size in (1, -1)
    b = {c.uid: c.tokens
         for c in ServeEngine(cfg, params, max_seq=64, n_slots=2).run(reqs())}
    assert a == b
    c = {c.uid: c.tokens
         for c in ServeEngine(cfg, params, max_seq=64,
                              n_slots=2).run(reqs(seed=14))}
    assert c["greedy"] == a["greedy"]
    assert c["hot"] != a["hot"]


# ---------------------------------------------------------------------------
# Admission policy (FIFO default, shortest-remaining-first opt-in)
# ---------------------------------------------------------------------------

def test_fifo_remains_default_and_srf_token_identical(setup):
    """The admission policy must never change token streams (per-lane
    chunk boundaries and decode math are schedule-independent); FIFO stays
    the default ordering, and SRF reorders admissions shortest-first."""
    cfg, api, params, absorbed, pj = setup
    reqs = lambda: [
        Request(uid="long", tokens=_prompt(cfg, 24, seed=1), max_new_tokens=8),
        Request(uid="mid", tokens=_prompt(cfg, 12, seed=2), max_new_tokens=6),
        Request(uid="short", tokens=_prompt(cfg, 5, seed=3), max_new_tokens=3),
    ]
    fifo_eng = ServeEngine(cfg, params, max_seq=64, n_slots=1)
    assert fifo_eng.admission == "fifo"          # regression: the default
    fifo = {c.uid: c for c in fifo_eng.run(reqs())}
    srf = {c.uid: c
           for c in ServeEngine(cfg, params, max_seq=64, n_slots=1,
                                admission="srf").run(reqs())}
    assert {u: c.tokens for u, c in fifo.items()} == \
        {u: c.tokens for u, c in srf.items()}
    # FIFO serves in submission order; SRF bounds short-request TTFT when
    # the queue exceeds slot capacity
    assert fifo["long"].first_token_step < fifo["short"].first_token_step
    assert srf["short"].first_token_step < srf["mid"].first_token_step
    assert srf["mid"].first_token_step < srf["long"].first_token_step
    assert srf["short"].first_token_step < fifo["short"].first_token_step


def test_srf_validation(setup):
    cfg, api, params, absorbed, pj = setup
    with pytest.raises(ValueError, match="admission"):
        ServeEngine(cfg, params, max_seq=64, n_slots=1, admission="lifo")


def test_cache_report(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(cfg, k_max=4, quantize=True),
                      projections=pj, max_seq=512, n_slots=2)
    rep = eng.cache_report()
    assert rep["bytes"] < rep["dense_bytes"]
    assert rep["saving"] > 0.0


# ---------------------------------------------------------------------------
# AOT lowering hooks (swanlint compiled-dispatch audit)
# ---------------------------------------------------------------------------

def test_lower_decode_and_chunk_audit_clean(setup):
    """The production decode/chunk executables, AOT-lowered via the same
    jitted callables step() dispatches through, must contain zero host
    transfers and zero collectives (the serve path is lane-local)."""
    from repro.analysis.hlo import analyze_hlo, transfer_stats
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, swan=_swan(cfg), projections=pj,
                      max_seq=64, n_slots=2, prefill_chunk=8,
                      prefill_slots=2)
    for low in (eng.lower_decode(), eng.lower_chunk()):
        txt = low.compile().as_text()
        ts = transfer_stats(txt)
        assert ts.host_total == 0 and ts.unmatched_async == 0
        assert analyze_hlo(txt).per_collective == {}


def test_lower_decode_paged_bucket_shapes(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, swan=_swan(cfg), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=16,
                      prefill_chunk=8)
    from repro.analysis.hlo import transfer_stats
    for pb in (1, 2):
        txt = eng.lower_decode(page_bucket=pb).compile().as_text()
        assert transfer_stats(txt).host_total == 0


def test_lower_requires_jit(setup):
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, params, swan=_swan(cfg), projections=pj,
                      max_seq=64, n_slots=1, jit=False)
    with pytest.raises(RuntimeError, match="jit"):
        eng.lower_decode()
