"""Batched concurrent prefill (engine ``prefill_slots``/``prefill_budget``):
under an admission burst the batched multi-slot scheduler must be
token-identical to the serial single-prefill scheduler (dense, SWAN-slab
and SWAN-paged, mixed per-request k, temperature lanes), no in-flight
prefill may starve under a constrained budget, TTFT for late-admitted
requests must drop vs the serial scheduler, and the packed multi-slot
executable count must stay O(log n_slots × log chunk × log max_seq)."""
import jax
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

CHUNK = 8
PAGE = 16
BUF = 4
# burst of mixed prompt lengths straddling chunk/page/buffer boundaries
BURST_LENS = [20, 33, 7, 15, 40, 9]


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def _prompt(cfg, n, seed=0):
    return np.asarray(make_batch(cfg, 1, n, seed=seed)["tokens"][0]).tolist()


def _burst(cfg, lossy_k=False):
    """Simultaneous admissions, mixed lengths; optionally mixed per-request
    k and a temperature lane (lossy-compression identity must hold too —
    per-lane chunk boundaries stay full chunks under any schedule)."""
    reqs = []
    for i, n in enumerate(BURST_LENS):
        kw = {}
        if lossy_k:
            kw["k"] = [8, 4, None][i % 3]
            if i == 2:
                kw.update(temperature=0.7, seed=9)
        reqs.append(Request(uid=f"r{i}", tokens=_prompt(cfg, n, seed=30 + i),
                            max_new_tokens=4, **kw))
    return reqs


def _run(cfg, params, reqs, **kw):
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=4, prefill_chunk=CHUNK,
                      **kw)
    comps = eng.run(reqs)
    return eng, {c.uid: c.tokens for c in comps}, \
        {c.uid: c.first_token_step for c in comps}


# ---------------------------------------------------------------------------
# Acceptance: batched concurrent == serial budget, token for token
# ---------------------------------------------------------------------------

def test_batched_matches_serial_dense(setup):
    cfg, api, params, absorbed, pj = setup
    _, want, _ = _run(cfg, params, _burst(cfg), prefill_slots=1)
    _, got, _ = _run(cfg, params, _burst(cfg), prefill_slots=4)
    assert got == want


def test_batched_matches_serial_slab_lossy_k(setup):
    """Mixed per-request k + a temperature lane at k_max < d_head: the
    batched scheduler reproduces the serial one token for token, because
    every lane always advances a full chunk (boundaries are
    schedule-independent)."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=8, buffer=BUF, mode="topk")
    kw = dict(swan=swan, projections=pj)
    _, want, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                      prefill_slots=1, **kw)
    _, got, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                     prefill_slots=4, **kw)
    assert got == want
    # a budget below P*chunk limits lanes per step but never shortens a
    # chunk — still token-identical
    _, got2, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                      prefill_slots=4, prefill_budget=2 * CHUNK, **kw)
    assert got2 == want


def test_batched_matches_serial_paged_lossy_k(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=8, buffer=BUF, mode="topk")
    kw = dict(swan=swan, projections=pj, paged=True, page_size=PAGE)
    _, want, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                      prefill_slots=1, **kw)
    eng, got, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                       prefill_slots=4, **kw)
    assert got == want
    assert eng.pool.live_pages == 0          # drained -> fully reclaimed
    eng.pool.check_consistent()
    # paged == slab under concurrent prefills too
    _, slab, _ = _run(cfg, absorbed, _burst(cfg, lossy_k=True),
                      prefill_slots=4, swan=swan, projections=pj)
    assert got == slab


# ---------------------------------------------------------------------------
# TTFT and fairness
# ---------------------------------------------------------------------------

def test_ttft_drops_for_late_admissions(setup):
    """Under the burst, the Nth admitted request's first-token step must
    drop vs the serial scheduler (the whole point: TTFT ~ O(prompt chunks),
    not O(queue depth × prompt chunks)), and no request may get slower."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head, buffer=BUF, mode="topk")
    kw = dict(swan=swan, projections=pj)
    ser_eng, _, ftt_ser = _run(cfg, absorbed, _burst(cfg),
                               prefill_slots=1, **kw)
    bat_eng, _, ftt_bat = _run(cfg, absorbed, _burst(cfg),
                               prefill_slots=4, **kw)
    assert all(ftt_bat[u] <= ftt_ser[u] for u in ftt_ser)
    # the LAST request to produce a first token must be strictly faster
    assert max(ftt_bat.values()) < max(ftt_ser.values())
    # equal decode throughput: the batched engine still takes one decode
    # dispatch per step and drains in no more steps than the serial one
    assert bat_eng.step_count <= ser_eng.step_count


def test_round_robin_no_starvation(setup):
    """More in-flight prefills than prefill_slots, budget pinned to
    prefill_slots chunks: the rotating pointer must keep every prefill
    advancing — equal-length simultaneous prompts finish their prefills
    within one round of each other instead of head-of-line blocking."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head, buffer=BUF, mode="topk")
    reqs = [Request(uid=f"f{i}", tokens=_prompt(cfg, 32, seed=70 + i),
                    max_new_tokens=2) for i in range(4)]
    eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj, max_seq=64,
                      n_slots=4, prefill_chunk=CHUNK, prefill_slots=2,
                      prefill_budget=2 * CHUNK)
    comps = eng.run(reqs)
    ftt = [c.first_token_step for c in comps]
    # 4 prompts x 4 chunks at 2 chunks/step = 8 steps of prefill work;
    # round-robin spreads them so first tokens land within one RR round
    assert max(ftt) - min(ftt) <= 1
    assert max(ftt) <= 8


# ---------------------------------------------------------------------------
# Executable bounds, table-upload caching, validation
# ---------------------------------------------------------------------------

def test_executables_bounded_under_burst(setup):
    """Packing P lanes must not multiply executables per in-flight-prefill
    combination: P buckets to a power of two and full chunks share one
    width, so the burst compiles O(log slots × log chunk × log max_seq)
    shapes."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head, buffer=BUF, mode="topk")
    eng, _, _ = _run(cfg, absorbed, _burst(cfg), prefill_slots=4,
                     swan=swan, projections=pj)
    if eng.prefill_cache_size != -1:
        # (P in {1,2,4}) x (C buckets) x (prefix buckets), loosely bounded
        bound = 3 * (CHUNK.bit_length() + 1 + 7)      # 3 * (log C + log S)
        assert eng.prefill_cache_size <= bound


def test_device_table_upload_cached(setup):
    """The device page-table prefix is re-uploaded only when the host table
    changed (pool.version dirty counter), not on every dispatch."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=8, buffer=BUF, mode="topk")
    eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj, max_seq=64,
                      n_slots=2, paged=True, page_size=PAGE,
                      prefill_chunk=CHUNK)
    v0 = eng.pool.version
    eng.pool.ensure(0, PAGE)                 # maps one page
    assert eng.pool.version > v0
    t1 = eng._device_table(2)
    assert eng._device_table(2) is t1        # clean table -> cached upload
    eng.pool.ensure(0, 2 * PAGE)             # second page -> dirty
    t2 = eng._device_table(2)
    assert t2 is not t1
    np.testing.assert_array_equal(np.asarray(t2), eng.pool.table[:, :2])
    assert eng.pool.free_slot(0) == 2        # retirement dirties it too
    assert eng._device_table(2) is not t2


def test_concurrent_prefill_validation(setup):
    cfg, api, params, absorbed, pj = setup
    with pytest.raises(ValueError, match="requires prefill_chunk"):
        ServeEngine(cfg, params, max_seq=64, n_slots=2, prefill_slots=2)
    with pytest.raises(ValueError, match="prefill_slots"):
        ServeEngine(cfg, params, max_seq=64, n_slots=2, prefill_chunk=8,
                    prefill_slots=0)
    with pytest.raises(ValueError, match="prefill_budget"):
        ServeEngine(cfg, params, max_seq=64, n_slots=2, prefill_chunk=8,
                    prefill_slots=2, prefill_budget=0)
    # prefill_slots is clamped to the slot count, not an error
    eng = ServeEngine(cfg, params, max_seq=64, n_slots=2, prefill_chunk=8,
                      prefill_slots=8)
    assert eng.prefill_slots == 2
    assert eng.prefill_budget == 2 * 8
