"""Property-based tests (hypothesis) for SWAN's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.analytical import (breakeven_length, compression_ratio,
                                   flops_standard, flops_swan,
                                   sparse_vector_bytes)
from repro.core.projections import gram_basis, random_orthogonal
from repro.core.winnow import (dequantize_int8, quantize_int8, topk_pack,
                               unpack_dense)

_SETTINGS = dict(max_examples=25, deadline=None)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(8, 64))
@settings(**_SETTINGS)
def test_rotation_preserves_dot_products(seed, n):
    """Lemma A.1 as a property: any orthogonal P preserves q·kᵀ."""
    key = jax.random.PRNGKey(seed)
    p = random_orthogonal(key, (), 16)
    q = jax.random.normal(jax.random.fold_in(key, 1), (n, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (n, 16))
    s0 = q @ k.T
    s1 = (q @ p) @ (k @ p).T
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-3)


@given(seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 16), dh=st.sampled_from([16, 32]))
@settings(**_SETTINGS)
def test_prune_idempotent(seed, k, dh):
    """Winnowing an already-winnowed vector changes nothing."""
    k = min(k, dh)
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, dh))
    v1, i1 = topk_pack(x, k)
    d1 = unpack_dense(v1, i1, dh)
    v2, i2 = topk_pack(d1, k)
    d2 = unpack_dense(v2, i2, dh)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_pruning_error_monotone_in_k(seed):
    """More retained dims -> no larger reconstruction error."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 32))
    errs = []
    for k in [4, 8, 16, 32]:
        v, i = topk_pack(x, k)
        errs.append(float(jnp.linalg.norm(unpack_dense(v, i, 32) - x)))
    assert all(a >= b - 1e-6 for a, b in zip(errs, errs[1:]))
    assert errs[-1] < 1e-6


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 100.0))
@settings(**_SETTINGS)
def test_quantization_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert bool(jnp.all(err <= bound + 1e-5))


@given(dh=st.sampled_from([64, 128]), k=st.integers(1, 127),
       b=st.integers(0, 512), L=st.integers(1, 100_000))
@settings(**_SETTINGS)
def test_breakeven_consistent_with_flop_model(dh, k, b, L):
    """Eq. 2 break-even point is exactly where the Prop A.3/A.4 FLOP models
    cross (k < dh)."""
    k = min(k, dh - 1)
    be = breakeven_length(dh, k, b)
    if L > be and L > b:
        assert flops_swan(L, dh, k, b) < flops_standard(L, dh)
    if L < min(be, b):   # fully-buffered region: SWAN adds projection cost
        assert flops_swan(L, dh, k, b) >= flops_standard(L, dh)


@given(k=st.integers(1, 128), bits8=st.booleans())
@settings(**_SETTINGS)
def test_memory_model_eq1(k, bits8):
    got = sparse_vector_bytes(k, bits8)
    assert got == (2 * k + 2 if bits8 else 3 * k + 2)
    # compression < 1 iff below the paper's break-even retention
    ratio = compression_ratio(k, 128, bits8)
    dense = 256
    assert abs(ratio - got / dense) < 1e-9


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(10, 200))
@settings(**_SETTINGS)
def test_gram_basis_reconstruction_optimality(seed, n):
    """Leading-j subspace captures at least as much energy as any random
    orthogonal subspace of the same rank (Eckart–Young flavour)."""
    key = jax.random.PRNGKey(seed)
    s = jax.random.normal(key, (n, 16)) * jnp.linspace(4, 0.2, 16)[None]
    p = gram_basis(s)
    j = 4
    proj = s @ p[:, :j]
    captured = float(jnp.sum(proj ** 2))
    p_rand = random_orthogonal(jax.random.fold_in(key, 3), (), 16)
    captured_rand = float(jnp.sum((s @ p_rand[:, :j]) ** 2))
    assert captured >= captured_rand - 1e-3
