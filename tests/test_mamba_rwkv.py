"""Sequence-mixer equivalences: chunked (training) formulations vs
sequential (decode) recurrences for Mamba and RWKV-6, and hybrid
prefill ≡ decode consistency for Jamba."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, mamba as mb, rwkv
from repro.launch.io import make_batch


def test_mamba_chunked_equals_sequential():
    cfg = get_smoke_config("jamba-1.5-large-398b").replace(
        dtype="float32", param_dtype="float32")
    p = mb.init_mamba_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, cfg.d_model)) * 0.5
    y_chunk = mb.mamba_forward(p, cfg, x, chunk=8)
    y_seq = mb.mamba_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=1e-5)


def test_mamba_chunk_size_invariance():
    cfg = get_smoke_config("jamba-1.5-large-398b").replace(
        dtype="float32", param_dtype="float32")
    p = mb.init_mamba_params(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 24, cfg.d_model)) * 0.5
    y8 = mb.mamba_forward(p, cfg, x, chunk=8)
    y24 = mb.mamba_forward(p, cfg, x, chunk=24)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y24), atol=1e-5)


def test_rwkv_chunked_equals_sequential():
    cfg = get_smoke_config("rwkv6-3b").replace(dtype="float32",
                                               param_dtype="float32")
    p = rwkv.init_time_mix_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 21, cfg.d_model)) * 0.5
    y_chunk = rwkv.time_mix_forward(p, cfg, x, chunk=8)
    # sequential oracle via decode steps
    state = {"S": jnp.zeros((2, cfg.n_heads, cfg.rwkv.head_dim,
                             cfg.rwkv.head_dim), jnp.float32),
             "x_tm": jnp.zeros((2, 1, cfg.d_model), jnp.float32),
             "x_cm": jnp.zeros((2, 1, cfg.d_model), jnp.float32)}
    outs = []
    for t in range(21):
        y, state = rwkv.time_mix_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4, rtol=1e-3)


def test_rwkv_channel_mix_decode_matches_forward():
    cfg = get_smoke_config("rwkv6-3b").replace(dtype="float32",
                                               param_dtype="float32")
    p = rwkv.init_channel_mix_params(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 9, cfg.d_model))
    y_fwd = rwkv.channel_mix_forward(p, cfg, x)
    state = {"x_cm": jnp.zeros((2, 1, cfg.d_model), jnp.float32)}
    outs = []
    for t in range(9):
        y, state = rwkv.channel_mix_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(y_fwd),
                               np.asarray(jnp.concatenate(outs, 1)), atol=1e-5)


@pytest.mark.parametrize("arch", ["rwkv6-3b", "jamba-1.5-large-398b"])
def test_prefill_matches_incremental_decode(arch):
    """prefill(S tokens) then decode == decode-from-scratch token by token.
    Validates recurrent-state reconstruction in the parallel prefill."""
    cfg = get_smoke_config(arch).replace(dtype="float32",
                                         param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 1, 10)
    tokens = batch["tokens"]

    st_p = api.init_serve_state(cfg, None, 1, 24)
    lg_prefill, st_p = api.prefill(params, cfg, batch, st_p)

    st_d = api.init_serve_state(cfg, None, 1, 24)
    for t in range(10):
        lg_step, st_d = api.decode_step(params, cfg, tokens[:, t], t, st_d)
    np.testing.assert_allclose(np.asarray(lg_prefill[:, -1]),
                               np.asarray(lg_step), atol=2e-3, rtol=1e-3)
    # continuing decode from both states must agree
    tok = jnp.argmax(lg_step, -1)
    lg_a, _ = api.decode_step(params, cfg, tok, 10, st_p)
    lg_b, _ = api.decode_step(params, cfg, tok, 10, st_d)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                               atol=2e-3, rtol=1e-3)


def test_dense_transformer_prefill_matches_decode():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 10)
    tokens = batch["tokens"]
    st_p = api.init_serve_state(cfg, None, 2, 24)
    lg_prefill, st_p = api.prefill(params, cfg, batch, st_p)
    st_d = api.init_serve_state(cfg, None, 2, 24)
    for t in range(10):
        lg_step, st_d = api.decode_step(params, cfg, tokens[:, t], t, st_d)
    np.testing.assert_allclose(np.asarray(lg_prefill[:, -1]),
                               np.asarray(lg_step), atol=1e-4, rtol=1e-4)
