"""Launcher CLI smoke tests (subprocess — the way operators invoke them)."""
import subprocess
import sys

CMD = [sys.executable, "-m"]
ENV_CWD = "/root/repo"


def _run(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m"] + args, capture_output=True, text=True,
        timeout=timeout, cwd=ENV_CWD,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"})


def test_train_cli(tmp_path):
    out = _run(["repro.launch.train", "--arch", "olmo-1b", "--smoke",
                "--steps", "4", "--batch", "2", "--seq", "16",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "finished at step 4" in out.stdout
    assert "loss" in out.stdout


def test_serve_cli_swan(tmp_path):
    out = _run(["repro.launch.serve", "--arch", "llama3-8b", "--smoke",
                "--swan", "--k", "8", "--buffer", "8", "--batch", "2",
                "--prompt-len", "8", "--tokens", "6", "--max-seq", "64"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "SWAN: k_max=8" in out.stdout
    assert "cache [swan[topk]]" in out.stdout


def test_serve_cli_rejects_swan_for_rwkv():
    out = _run(["repro.launch.serve", "--arch", "rwkv6-3b", "--smoke",
                "--swan", "--tokens", "2", "--max-seq", "32"])
    assert out.returncode != 0
    assert "inapplicable" in (out.stdout + out.stderr)


def test_dryrun_cli_single_cell(tmp_path):
    out = _run(["repro.launch.dryrun", "--arch", "olmo-1b",
                "--shape", "decode_32k", "--swan", "--out", str(tmp_path)],
               timeout=560)
    assert out.returncode == 0, out.stderr[-1500:]
    assert "ok" in out.stdout
    import glob
    import json
    rec = json.load(open(glob.glob(str(tmp_path / "*.json"))[0]))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert "roofline" in rec and "kernel_model_memory_s" in rec["roofline"]
