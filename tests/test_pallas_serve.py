"""Pallas fast path on the serve hot path: kernel-vs-pure-JAX equivalence
(interpret mode — TPU semantics executed on CPU) and engine-level token
identity with ``use_pallas`` on vs off.

Covers the tentpole contract: slab and paged decode kernels, the paged
in-kernel page gather (trash pages, ring wrap, buffer-straddling
positions), the bulk-chunk prefill stats kernel, dispatch eligibility
resolution, and the ServeEngine threading (mixed per-request k,
temperature lanes, concurrent chunked prefill with dead lanes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.core import swan_attention as swa
from repro.kernels.dispatch import (pallas_decode_supported,
                                    resolve_interpret, resolve_use_pallas)
from repro.kernels.flash_prefill.swan_chunk import (
    swan_chunk_stats_paged_pallas, swan_chunk_stats_pallas)
from repro.kernels.swan_decode.ops import (swan_decode_attention_kernel_paged,
                                           swan_decode_paged_from_cache)


def _unique_idx(rng, shape, dh):
    k = shape[-1]
    flat = np.stack([rng.permutation(dh)[:k]
                     for _ in range(int(np.prod(shape[:-1])))])
    return jnp.asarray(flat.reshape(shape), jnp.int8)


# ---------------------------------------------------------------------------
# Dispatch policy
# ---------------------------------------------------------------------------

def test_resolve_defaults_follow_backend():
    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_use_pallas(None) == on_tpu
    assert resolve_interpret(True) and not resolve_interpret(False)
    assert resolve_use_pallas(True) and not resolve_use_pallas(False)


def test_pallas_decode_supported_gates():
    assert not pallas_decode_supported(None)
    assert pallas_decode_supported(SwanConfig(k_max=8, buffer=4, mode="topk"))
    assert not pallas_decode_supported(
        SwanConfig(k_max=8, buffer=4, mode="truncate"))
    assert not pallas_decode_supported(
        SwanConfig(k_max=8, buffer=0, mode="topk"))


# ---------------------------------------------------------------------------
# Paged decode kernel vs the pure-JAX logical-view path
# ---------------------------------------------------------------------------

def _paged_fixture(rng, *, B, Kv, ps, n_log, dh, k, b, quant=False):
    """Pool + table + ring with per-sequence positions chosen so lanes mix
    ring wrap, partially-filled pages, and trash-backed table tails."""
    n_pages = B * n_log + 1
    def side():
        s = {"vals": (jnp.asarray(rng.integers(-127, 128,
                                               (n_pages, Kv, ps, k)),
                                  jnp.int8) if quant else
                      jnp.asarray(rng.standard_normal((n_pages, Kv, ps, k)),
                                  jnp.float32)),
             "idx": _unique_idx(rng, (n_pages, Kv, ps, k), dh)}
        if quant:
            s["scale"] = jnp.asarray(rng.random((n_pages, Kv, ps)) * 0.1
                                     + 0.01, jnp.float32)
        return s
    # per-lane positions: lane 0 full view, later lanes shorter prefixes
    # (their unmapped tail entries point at the trash page 0)
    pos = np.array([n_log * ps + b - 1 - 7 * i for i in range(B)], np.int32)
    sp = np.maximum(pos + 1 - b, 0)
    tab = np.zeros((B, n_log), np.int32)
    for lane in range(B):
        n_mapped = min(n_log, -(-int(sp[lane]) // ps) or 1)
        tab[lane, :n_mapped] = 1 + lane * n_log + np.arange(n_mapped)
    bpos = np.zeros((B, b), np.int32)
    for lane in range(B):
        for p in range(int(pos[lane]) - b + 1, int(pos[lane]) + 1):
            bpos[lane, p % b] = p          # ring wrap: slot = pos % b
    cache = {
        "pool": {"k": side(), "v": side()},
        "buf_k": jnp.asarray(rng.standard_normal((B, Kv, b, dh)),
                             jnp.float32),
        "buf_v": jnp.asarray(rng.standard_normal((B, Kv, b, dh)),
                             jnp.float32),
        "buf_pos": jnp.asarray(bpos),
    }
    return cache, jnp.asarray(tab), jnp.asarray(pos)


@pytest.mark.parametrize("quant", [False, True])
def test_paged_decode_kernel_matches_pure(quant):
    rng = np.random.default_rng(5)
    B, Kv, G, dh, ps, n_log, k, b = 3, 2, 2, 32, 16, 4, 8, 8
    cfg = get_smoke_config("llama3-8b").replace(
        n_kv_heads=Kv, n_heads=Kv * G, d_head=dh, dtype="float32")
    swan = SwanConfig(k_max=k, buffer=b, mode="topk", quantize=quant)
    cache, tab, pos = _paged_fixture(rng, B=B, Kv=Kv, ps=ps, n_log=n_log,
                                     dh=dh, k=k, b=b, quant=quant)
    q = jnp.asarray(rng.standard_normal((B, Kv, G, dh)), jnp.float32)
    o_ref = swa.swan_decode_attention_paged(q, cache, swan, cfg, pos, tab)
    o_ker = swan_decode_paged_from_cache(q, cache, swan, pos, tab)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               atol=5e-5)
    # jitted wrapper (the form the serve decode body uses)
    o_jit = swan_decode_attention_kernel_paged(q, cache, swan, cfg, pos, tab)
    np.testing.assert_allclose(np.asarray(o_jit), np.asarray(o_ref),
                               atol=5e-5)


# ---------------------------------------------------------------------------
# Bulk-chunk prefill stats kernel vs _sparse_stats_bulk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_chunk_stats_kernel_matches_bulk(quant):
    rng = np.random.default_rng(6)
    B, Kv, Q, dh, S, k = 2, 2, 8, 32, 48, 8
    swan = SwanConfig(k_max=k, buffer=4, mode="topk", quantize=quant)
    def side():
        s = {"vals": (jnp.asarray(rng.integers(-127, 128, (B, Kv, S, k)),
                                  jnp.int8) if quant else
                      jnp.asarray(rng.standard_normal((B, Kv, S, k)),
                                  jnp.float32)),
             "idx": _unique_idx(rng, (B, Kv, S, k), dh)}
        if quant:
            s["scale"] = jnp.asarray(rng.random((B, Kv, S)) * 0.1 + 0.01,
                                     jnp.float32)
        return s
    ks_, vs_ = side(), side()
    q = jnp.asarray(rng.standard_normal((B, Kv, Q, dh)), jnp.float32)
    sp = jnp.asarray([S - 5, 0], jnp.int32)       # lane 1: empty prefix
    m_r, l_r, o_r = swa._sparse_stats_bulk(q, ks_, vs_, swan, sp, dh)
    m_k, l_k, o_k = swan_chunk_stats_pallas(
        q, ks_["vals"], ks_["idx"], vs_["vals"], vs_["idx"], sp,
        k_scale=ks_.get("scale"), v_scale=vs_.get("scale"), block_s=16)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=5e-5)


def test_chunk_stats_paged_kernel_matches_bulk_on_view():
    rng = np.random.default_rng(7)
    B, Kv, Q, dh, ps, n_log, k, b = 2, 2, 6, 32, 16, 3, 8, 8
    swan = SwanConfig(k_max=k, buffer=b, mode="topk")
    cache, tab, pos = _paged_fixture(rng, B=B, Kv=Kv, ps=ps, n_log=n_log,
                                     dh=dh, k=k, b=b)
    sp = jnp.maximum(pos + 1 - b, 0)
    q = jnp.asarray(rng.standard_normal((B, Kv, Q, dh)), jnp.float32)
    view = swa.paged_logical_view(cache, tab)
    m_r, l_r, o_r = swa._sparse_stats_bulk(q, view["k"], view["v"], swan,
                                           sp, dh)
    pk, pv = cache["pool"]["k"], cache["pool"]["v"]
    m_k, l_k, o_k = swan_chunk_stats_paged_pallas(
        q, pk["vals"], pk["idx"], pv["vals"], pv["idx"], sp, tab)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), atol=5e-5)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=5e-5)


# ---------------------------------------------------------------------------
# ServeEngine: use_pallas on == off, token for token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_serve():
    from repro.launch.io import make_batch
    from repro.models import get_model
    from repro.runtime.serve_loop import calibrate_swan

    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 32, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    return cfg, absorbed, pj, make_batch


def _requests(cfg, make_batch, n=5):
    from repro.runtime.serve_engine import Request
    out = []
    for i in range(n):
        plen = max(4, 20 - 3 * (i % 4))           # mixed lengths -> dead lanes
        toks = make_batch(cfg, 1, plen, seed=100 + i)["tokens"][0]
        out.append(Request(uid=f"r{i}", tokens=[int(t) for t in toks],
                           max_new_tokens=12,     # > 2*buffer: ring wraps
                           temperature=0.7 if i % 3 == 0 else 0.0, seed=i,
                           k=[8, 4, 2][i % 3]))
    return out


@pytest.mark.parametrize("paged", [False, True])
def test_engine_pallas_token_identity(tiny_serve, paged):
    from repro.runtime.serve_engine import ServeEngine

    cfg, absorbed, pj, make_batch = tiny_serve
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    kw = dict(paged=True, page_size=16) if paged else {}

    def run(use_pallas):
        eng = ServeEngine(cfg, absorbed, swan=swan, projections=pj,
                          max_seq=64, n_slots=3, prefill_chunk=8,
                          prefill_slots=2, use_pallas=use_pallas, **kw)
        comps = eng.run(_requests(cfg, make_batch))
        return {c.uid: c.tokens for c in comps}, eng

    t_ref, e_ref = run(False)
    t_pal, e_pal = run(True)
    assert e_pal.use_pallas and not e_ref.use_pallas
    assert t_ref == t_pal
    # one chunk + one decode dispatch per step, independent of the backend
    assert e_pal.dispatches == e_ref.dispatches
    # every hot-path dispatch on the pallas engine went through the kernels
    for kind in ("decode", "chunk"):
        assert e_pal.metrics.value("serve_pallas_dispatch_total",
                                   kind=kind) == e_pal.dispatches[kind]
        h = e_pal.metrics.get("serve_dispatch_ms", kind=kind,
                              kernel="pallas")
        assert h is not None and h.count == e_pal.dispatches[kind]
        assert e_ref.metrics.value("serve_pallas_dispatch_total",
                                   kind=kind) == 0
        h_ref = e_ref.metrics.get("serve_dispatch_ms", kind=kind,
                                  kernel="xla")
        assert h_ref is not None and h_ref.count == e_ref.dispatches[kind]


def test_engine_use_pallas_rejects_non_kernel_path(tiny_serve):
    from repro.runtime.serve_engine import ServeEngine

    cfg, absorbed, pj, _ = tiny_serve
    with pytest.raises(ValueError, match="use_pallas"):
        ServeEngine(cfg, absorbed, max_seq=64, n_slots=2, use_pallas=True)
    with pytest.raises(ValueError, match="use_pallas"):
        ServeEngine(cfg, absorbed,
                    swan=SwanConfig(k_max=8, buffer=4, mode="truncate"),
                    projections=pj, max_seq=64, n_slots=2, use_pallas=True)
