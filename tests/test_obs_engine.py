"""Observability x ServeEngine integration: the contract the subsystem
must keep is that it OBSERVES the engine without participating in it —
metrics/tracing on vs off produces identical tokens and identical dispatch
counts — plus per-request latency accounting (``first_token_step`` set
exactly once, inter-token gaps matching the trace) and byte-accounting
consistency (``cache_report()`` == the per-step gauges; both read
``ServeEngine._cache_bytes()``)."""
import jax
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.obs import EventTrace, MetricsRegistry, parse_prometheus
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import ServeSession, calibrate_swan

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = make_batch(cfg, 2, 24, seed=3)
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def _prompt(cfg, n, seed=0):
    return np.asarray(make_batch(cfg, 1, n, seed=seed)["tokens"][0]).tolist()


def _swan(**kw):
    kw.setdefault("k_max", 8)
    kw.setdefault("buffer", 4)
    kw.setdefault("mode", "topk")
    return SwanConfig(**kw)


_SPEC = [(6, 8, 8, 0), (11, 5, 4, 0), (17, 9, None, 2), (9, 6, 2, 4)]


def _mixed_trace(cfg):
    """Mixed prompt lengths, mixed per-request k, staggered arrivals."""
    return [Request(uid=f"m{i}", tokens=_prompt(cfg, n, seed=20 + i),
                    max_new_tokens=g, k=k, arrival_step=a)
            for i, (n, g, k, a) in enumerate(_SPEC)]


_ENGINE_KW = dict(max_seq=64, n_slots=2, paged=True, page_size=PAGE,
                  prefill_chunk=8, prefill_slots=2)


@pytest.fixture(scope="module")
def obs_run(setup):
    """One drained, fully instrumented engine on the full serving feature
    surface: paged pool + chunked + batched concurrent prefill."""
    cfg, api, params, absorbed, pj = setup
    trace = EventTrace()
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      trace=trace, **_ENGINE_KW)
    comps = eng.run(_mixed_trace(cfg))
    return cfg, eng, trace, comps


# ---------------------------------------------------------------------------
# The contract: observation never participates
# ---------------------------------------------------------------------------

def test_metrics_on_vs_off_token_and_dispatch_identity(setup, obs_run):
    """The tentpole regression gate: the fully instrumented engine and a
    metrics=False, trace=None engine produce IDENTICAL tokens, dispatch
    counts and step counts on the same trace."""
    cfg, api, params, absorbed, pj = setup
    _, on, _, on_comps = obs_run
    off = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      metrics=False, **_ENGINE_KW)
    off_comps = off.run(_mixed_trace(cfg))
    assert {c.uid: c.tokens for c in off_comps} \
        == {c.uid: c.tokens for c in on_comps}
    assert dict(off.dispatches) == dict(on.dispatches)
    assert off.step_count == on.step_count
    assert [(c.uid, c.admitted_step, c.first_token_step, c.finished_step)
            for c in off_comps] \
        == [(c.uid, c.admitted_step, c.first_token_step, c.finished_step)
            for c in on_comps]
    # off really is off: the null registry never accumulates state
    assert not off.metrics.enabled
    assert off.metrics.snapshot() == {"metrics": {}}


# ---------------------------------------------------------------------------
# Per-request latency accounting
# ---------------------------------------------------------------------------

def test_first_token_step_set_exactly_once_concurrent(obs_run):
    """Concurrent chunked prefill (the greedy first-token-from-chunk
    path): one ``first_token`` event per request, at the completion's
    ``first_token_step``, with TTFT = first_token_step - arrival_step."""
    cfg, eng, trace, comps = obs_run
    arrivals = {f"m{i}": a for i, (_, _, _, a) in enumerate(_SPEC)}
    for c in comps:
        evs = trace.select("first_token", uid=c.uid)
        assert len(evs) == 1, f"{c.uid}: first_token emitted {len(evs)}x"
        assert evs[0]["step"] == c.first_token_step
        assert evs[0]["ttft_steps"] == c.first_token_step - arrivals[c.uid]
        assert c.admitted_step <= c.first_token_step <= c.finished_step
        # the index-0 token event coincides with prefill completion
        tok0 = trace.select("token", uid=c.uid, index=0)
        assert len(tok0) == 1 and tok0[0]["step"] == c.first_token_step
        assert tok0[0]["token"] == c.tokens[0]
    ttft = eng.metrics.get("serve_ttft_steps")
    assert ttft.count == len(comps)
    assert ttft.sum == sum(c.first_token_step - arrivals[c.uid]
                           for c in comps)


@pytest.mark.parametrize("chunk", [None, 8],
                         ids=["monolithic", "chunked_serial"])
def test_first_token_step_set_exactly_once(setup, chunk):
    """Monolithic admission and serial (one-slot) chunked prefill keep the
    same first-token invariants as the concurrent path."""
    cfg, api, params, absorbed, pj = setup
    trace = EventTrace()
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, prefill_chunk=chunk,
                      trace=trace)
    comps = eng.run(_mixed_trace(cfg))
    assert len(comps) == len(_SPEC)
    for c in comps:
        evs = trace.select("first_token", uid=c.uid)
        assert len(evs) == 1
        assert evs[0]["step"] == c.first_token_step >= c.admitted_step
    assert eng.metrics.get("serve_ttft_steps").count == len(comps)


def test_inter_token_gaps_match_trace(obs_run):
    """The ``serve_inter_token_steps`` histogram must agree exactly with
    the per-request gaps reconstructed from ``token`` trace events."""
    cfg, eng, trace, comps = obs_run
    gaps = []
    for c in comps:
        steps = [e["step"] for e in trace.select("token", uid=c.uid)]
        assert len(steps) == len(c.tokens)
        assert steps == sorted(steps)
        gaps += [b - a for a, b in zip(steps, steps[1:])]
    h = eng.metrics.get("serve_inter_token_steps")
    assert h.count == len(gaps)
    assert h.sum == sum(gaps)
    # gap 0 is legal: a slot can finish prefill and join the decode
    # dispatch within the same engine step
    assert all(g >= 0 for g in gaps)
    assert eng.metrics.value("serve_tokens_generated_total") \
        == sum(len(c.tokens) for c in comps)


def test_retire_events_match_completions(obs_run):
    cfg, eng, trace, comps = obs_run
    for c in comps:
        (ev,) = trace.select("retire", uid=c.uid)
        assert ev["n_tokens"] == len(c.tokens)
        assert ev["step"] == c.finished_step
        assert ev["first_token_step"] == c.first_token_step
        assert ev["reason"] in ("eos", "max_tokens", "max_seq")
    done = sum(s.value for s in
               eng.metrics._families["serve_completions_total"]
               ["series"].values())
    assert done == len(comps)
    assert eng.metrics.get("serve_request_steps").count == len(comps)


# ---------------------------------------------------------------------------
# Byte accounting: one source of truth
# ---------------------------------------------------------------------------

def test_cache_report_matches_gauges_paged(obs_run):
    """cache_report() and the per-step gauges read the SAME
    _cache_bytes() — after the drain they must agree exactly."""
    cfg, eng, trace, comps = obs_run
    rep = eng.cache_report()
    m = eng.metrics
    assert m.value("kv_cache_reserved_bytes") == rep["reserved_bytes"]
    assert m.value("kv_cache_live_bytes") == rep["live_bytes"]
    assert m.value("page_table_shipped_bytes") \
        == rep["page_table_shipped_bytes"]
    assert m.value("page_pool_live_pages") == rep["live_pages"] == 0
    assert m.value("shard_kv_cache_reserved_bytes", shard=0) \
        == rep["shards"][0]["reserved_bytes"]
    assert m.value("shard_kv_cache_live_bytes", shard=0) \
        == rep["shards"][0]["live_bytes"]
    # per-shard entries still sum exactly to the totals
    assert sum(s["reserved_bytes"] for s in rep["shards"]) \
        == rep["reserved_bytes"]
    assert m.value("serve_engine_steps") == eng.step_count


def test_slab_gauges_reserved_equals_live(setup):
    """Slab engines commit worst case up front: the gauges show
    reserved == live every step, matching cache_report()."""
    cfg, api, params, absorbed, pj = setup
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2)
    eng.run(_mixed_trace(cfg))
    rep = eng.cache_report()
    assert rep["reserved_bytes"] == rep["live_bytes"]
    assert eng.metrics.value("kv_cache_reserved_bytes") \
        == eng.metrics.value("kv_cache_live_bytes") == rep["live_bytes"]


# ---------------------------------------------------------------------------
# Page pool: allocator counters and events
# ---------------------------------------------------------------------------

def test_page_counters_balance_after_drain(obs_run):
    cfg, eng, trace, comps = obs_run
    m = eng.metrics
    mapped = m.value("page_pool_pages_mapped_total")
    freed = m.value("page_pool_pages_freed_total")
    assert mapped > 0
    assert mapped == freed, "drained pool must free every mapped page"
    assert len(trace.select("page_map")) == mapped
    assert sum(e["n_pages"] for e in trace.select("page_free")) == freed


def test_pool_grow_counter_and_event(setup):
    cfg, api, params, absorbed, pj = setup
    trace = EventTrace()
    eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                      max_seq=64, n_slots=2, paged=True, page_size=PAGE,
                      n_pages=2, pool_grow=True, trace=trace)
    eng.run(_mixed_trace(cfg))
    grows = eng.metrics.value("page_pool_grows_total")
    assert grows >= 1
    evs = trace.select("pool_grow")
    assert len(evs) == grows
    assert all(e["pages_per_shard_new"] > e["pages_per_shard_old"]
               for e in evs)


# ---------------------------------------------------------------------------
# Exporters over a real engine registry
# ---------------------------------------------------------------------------

def test_engine_registry_round_trips(obs_run):
    cfg, eng, trace, comps = obs_run
    snap = eng.metrics.snapshot()
    assert MetricsRegistry.from_snapshot(snap).snapshot() == snap
    parsed = parse_prometheus(eng.metrics.to_prometheus())
    for name in eng.metrics.names():
        assert name in parsed["types"], f"{name} missing from exposition"


def test_shared_registry_across_engines(setup):
    """Passing one MetricsRegistry into several engines aggregates their
    series instead of overwriting (counters just keep counting)."""
    cfg, api, params, absorbed, pj = setup
    reg = MetricsRegistry()
    for _ in range(2):
        eng = ServeEngine(cfg, absorbed, swan=_swan(), projections=pj,
                          max_seq=64, n_slots=2, metrics=reg)
        assert eng.metrics is reg
        eng.run(_mixed_trace(cfg)[:2])
    assert reg.value("serve_requests_submitted_total") == 4


# ---------------------------------------------------------------------------
# ServeSession (lockstep) metrics
# ---------------------------------------------------------------------------

def test_serve_session_metrics(setup):
    cfg, api, params, absorbed, pj = setup
    sess = ServeSession(cfg, params, max_seq=64, batch=2, metrics=True)
    out = sess.generate(make_batch(cfg, 2, 8, seed=7), 5)
    assert out.shape == (2, 5)
    m = sess.metrics
    assert m.value("session_prefill_total") == 1
    assert m.value("session_decode_total") == 4      # n_tokens - 1 decodes
    assert m.value("session_tokens_generated_total") == 10
    assert m.get("session_decode_call_ms").count == 4
    # default stays off — no registry unless asked
    off = ServeSession(cfg, params, max_seq=64, batch=1, jit=False)
    assert not off.metrics.enabled
