"""Paper-exact analytical checks: the numeric examples of Appendix A.2.1
and the Fig. 2a break-even thresholds must reproduce to the digit."""
import pytest

from repro.core.analytical import (breakeven_length, compression_ratio,
                                   memory_breakeven_retention,
                                   model_cache_footprint)
from repro.configs import SwanConfig, get_config


def test_appendix_a21_no_buffer():
    assert breakeven_length(128, 32, 0) == pytest.approx(170.67, abs=0.1)
    assert breakeven_length(128, 64, 0) == 256
    assert breakeven_length(128, 96, 0) == 512


def test_appendix_a21_with_buffer():
    assert breakeven_length(128, 32, 128) == pytest.approx(298.67, abs=0.1)
    assert breakeven_length(128, 64, 128) == 384
    assert breakeven_length(128, 96, 128) == 640


def test_fig2a_memory_breakeven():
    """'For 16-bit values, savings begin only when retention < 0.66'."""
    assert memory_breakeven_retention(128) == pytest.approx(0.661, abs=0.005)
    # 8-bit: 'almost one-to-one'
    assert memory_breakeven_retention(128, bits8=True) == pytest.approx(
        0.992, abs=0.01)


def test_fig2a_curve_points():
    assert compression_ratio(128, 128) > 1.0        # no pruning -> overhead
    assert compression_ratio(64, 128) == pytest.approx((3 * 64 + 2) / 256)
    assert compression_ratio(64, 128, bits8=True) == pytest.approx(
        (2 * 64 + 2) / 256)


def test_llama_paper_motivating_example():
    """Intro: Llama-2-7B-like model, 32k tokens, batch 16 -> ~256 GB dense
    KV cache (paper quotes 256 GB for fp16 MHA 32L/4096)."""
    cfg = get_config("llama3-8b").replace(n_kv_heads=32)   # MHA like llama2-7b
    swan = SwanConfig(k_max=64, buffer=128)
    fp = model_cache_footprint(cfg, swan, batch=16, seq=32_768)
    assert 200e9 < fp.dense_bytes < 300e9
    assert fp.saving > 0.2


def test_50_60_percent_savings_claim():
    """Abstract: '50-60% memory savings per-token' — k=48..64 of 128 with
    8-bit values lands in that band."""
    cfg = get_config("llama3-8b")
    for k, bits8 in [(64, True), (48, True)]:
        swan = SwanConfig(k_max=k, buffer=128, quantize=bits8)
        fp = model_cache_footprint(cfg, swan, batch=32, seq=32_768)
        assert 0.4 < fp.saving < 0.65, (k, bits8, fp.saving)
