"""Sharding: spec validity for every arch, sanitizer behaviour, and a
subprocess 8-device mini dry-run + sharded train step (the only way to get
multiple devices in this test process-space)."""
import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import get_model
from repro.sharding.serve_specs import (sanitize_tree, serve_state_pspecs)
from repro.sharding.specs import params_pspecs

from jax.sharding import NamedSharding, PartitionSpec as P


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_constructible(arch):
    """Every full-config param leaf gets a spec that (a) builds a
    NamedSharding and (b) divides the dim sizes after sanitizing."""
    cfg = get_config(arch)
    api = get_model(cfg)
    params_abs = api.abstract_params(cfg)
    mesh = make_mesh((1,), ("x",))  # placeholder; use production names below

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    specs = params_pspecs(params_abs, cfg, FakeMesh())
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree_util.tree_leaves(params_abs)
    assert len(flat_s) == len(flat_p)
    for spec, leaf in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            n = int(np.prod([FakeMesh.shape[a] for a in axes]))
            assert dim % n == 0, (arch, spec, leaf.shape)


def test_sanitizer_drops_indivisible():
    mesh = make_mesh((1,), ("model",))

    class M:
        shape = {"model": 16}
        axis_names = ("model",)

    import jax.numpy as jnp
    from repro.sharding.serve_specs import _sanitize
    out = _sanitize(P("model", None), (10, 4), M())
    assert tuple(out) == (None, None)
    out = _sanitize(P("model", None), (32, 4), M())
    assert tuple(out)[0] == "model"


_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import OptimizerConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.launch.mesh import make_mesh
from repro.models import get_model
from repro.optim.adamw import init_opt_state
from repro.runtime.train_loop import make_train_step
from repro.sharding.api import use_rules
from repro.sharding.serve_specs import batch_shardings, sanitize_tree
from repro.sharding.specs import activation_rules, params_pspecs

cfg = get_smoke_config("llama3-8b")
mesh = make_mesh((2, 4), ("data", "model"))
api = get_model(cfg)
params = api.init_params(jax.random.PRNGKey(0), cfg)
p_specs = sanitize_tree(params_pspecs(params, cfg, mesh), params, mesh)
p_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), p_specs)
params = jax.device_put(params, p_sh)
opt = init_opt_state(params, OptimizerConfig())
o_sh = {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}
opt = jax.device_put(opt, o_sh)
batch = make_batch(cfg, 4, 16)
batch = jax.device_put(batch, batch_shardings(batch, mesh))
step = make_train_step(cfg, OptimizerConfig(lr=1e-3), 1)
rules = activation_rules(cfg, mesh)
with use_rules(rules):
    jitted = jax.jit(step)
    params, opt, metrics = jitted(params, opt, batch)
    params, opt, metrics = jitted(params, opt, batch)
print(json.dumps({"loss": float(metrics["loss"]),
                  "grad_norm": float(metrics["grad_norm"]),
                  "n_dev": jax.device_count()}))
"""


def test_subprocess_8device_sharded_train():
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                         capture_output=True, text=True, timeout=420,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["n_dev"] == 8
    assert np.isfinite(rec["loss"]) and rec["loss"] > 0
    assert np.isfinite(rec["grad_norm"])


_DRYRUN_MINI = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod
import jax
# shrink the production mesh so the mini dry-run fits 8 host devices
mesh_mod.make_production_mesh = lambda multi_pod=False: mesh_mod.make_mesh(
    (2, 2, 2) if multi_pod else (2, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
dr.make_production_mesh = mesh_mod.make_production_mesh
recs = []
for mp in (False, True):
    rec = dr.build_cell("olmo-1b", "decode_32k", mp, True)
    recs.append({"status": rec["status"], "mp": mp,
                 "dom": rec.get("roofline", {}).get("bottleneck")})
print(json.dumps(recs))
"""


def test_subprocess_mini_dryrun_multipod():
    """build_cell compiles on a small 3-axis (pod,data,model) mesh —
    validates the multi-pod code path end-to-end inside the test suite."""
    out = subprocess.run([sys.executable, "-c", _DRYRUN_MINI],
                         capture_output=True, text=True, timeout=420,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    recs = json.loads(out.stdout.strip().splitlines()[-1])
    for rec in recs:
        assert rec["status"] == "ok", rec


def test_serve_state_specs_cover_all_archs():
    class M:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    from repro.configs import SwanConfig
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        api = get_model(cfg)
        state = jax.eval_shape(lambda: api.init_serve_state(cfg, None, 2, 32))
        specs = serve_state_pspecs(state, M())
        assert len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))) == \
            len(jax.tree_util.tree_leaves(state))


def test_serve_state_specs_leaf_complete_for_engine_states():
    """Every leaf of a REAL engine serve state — dense, slab (SWAN incl.
    quantized scales), and paged — must have an EXPLICIT spec rule, not the
    replicated fallback: the mesh-sharded engine builds its shard_map specs
    from this table, and an unspecced leaf would silently ship (and be
    written) replicated on every shard.  New state leaves can't land
    without a sharding decision."""
    from repro.configs import SwanConfig
    from repro.sharding.serve_specs import unspecced_serve_leaves

    cfg = get_smoke_config("llama3-8b")
    api = get_model(cfg)
    swan = SwanConfig(k_max=8, buffer=4, mode="topk", quantize=True)
    states = {
        "dense": jax.eval_shape(
            lambda: api.init_serve_state(cfg, None, 2, 32)),
        "slab": jax.eval_shape(
            lambda: api.init_serve_state(cfg, swan, 2, 32)),
        "paged": jax.eval_shape(
            lambda: api.init_paged_state(cfg, swan, 2, 32, 8, 8)),
    }
    for name, state in states.items():
        missing = unspecced_serve_leaves(state)
        assert not missing, f"{name} serve state has unspecced leaves: " \
                            f"{missing}"


def test_sanitizer_drops_axes_missing_from_mesh():
    """A data-only serve mesh must be able to consume the production specs
    (which also name 'model'): axes the mesh doesn't carry are dropped
    instead of raising."""
    from repro.sharding.serve_specs import _sanitize

    class M:
        shape = {"data": 2}
        axis_names = ("data",)

    out = _sanitize(P(None, "data", None, "model", None),
                    (2, 4, 2, 32, 8), M())
    assert tuple(out) == (None, "data", None, None, None)
