"""Pipeline parallelism: forward equality vs the plain stacked scan, and
gradient flow through the ppermute schedule (subprocess multi-device)."""
import json
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
from repro.launch.mesh import make_mesh
from repro.runtime.pipeline_parallel import pipeline_apply, split_stages

L, D, B = 8, 16, 12
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
bs = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
params = {"w": ws, "b": bs}
x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

def layer_fn(lp, x):
    return jnp.tanh(x @ lp["w"] + lp["b"])

def ref_apply(params, x):
    def body(x, lp):
        return layer_fn(lp, x), None
    out, _ = jax.lax.scan(body, x, params)
    return out

mesh = make_mesh((4,), ("pipe",))
stage_params = split_stages(params, 4)

y_ref = ref_apply(params, x)
y_pipe = pipeline_apply(layer_fn, stage_params, x, n_micro=3, mesh=mesh)
fwd_err = float(jnp.max(jnp.abs(y_ref - y_pipe)))

def loss_ref(params):
    return jnp.sum(ref_apply(params, x) ** 2)

def loss_pipe(sp):
    return jnp.sum(pipeline_apply(layer_fn, sp, x, n_micro=3, mesh=mesh) ** 2)

g_ref = jax.grad(loss_ref)(params)
g_pipe = jax.grad(loss_pipe)(stage_params)
g_pipe_flat = jax.tree_util.tree_map(
    lambda t: t.reshape(-1, *t.shape[2:]), g_pipe)
g_err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
    jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_pipe_flat)))
print(json.dumps({"fwd_err": fwd_err, "grad_err": g_err}))
"""


def test_pipeline_matches_reference_with_grads():
    out = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=420,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["fwd_err"] < 1e-5, rec
    assert rec["grad_err"] < 1e-4, rec
