"""Chunked prefill (engine ``prefill_chunk``): token-identity against
monolithic admission across prompt lengths straddling chunk/page/buffer
boundaries on dense, SWAN-slab and SWAN-paged engines; layout-identity
(paged == slab) under lossy compression; admission/retirement interleaving
while a prefill is mid-chunk; and executable-count bounds."""
import jax
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

CHUNK = 8
PAGE = 16
BUF = 4
# straddles chunk (8), page (16) and buffer (4) boundaries, incl. exact hits
STRADDLE_LENS = [3, 7, 8, 9, 15, 16, 17, 20]


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def _prompt(cfg, n, seed=0):
    return np.asarray(make_batch(cfg, 1, n, seed=seed)["tokens"][0]).tolist()


def _exact_swan(cfg):
    """Full retention: winnowing is exact, so chunked == monolithic."""
    return SwanConfig(k_max=cfg.d_head, buffer=BUF, mode="topk")


def _straddle_reqs(cfg):
    return [Request(uid=f"r{i}", tokens=_prompt(cfg, n, seed=30 + i),
                    max_new_tokens=5)
            for i, n in enumerate(STRADDLE_LENS)]


# ---------------------------------------------------------------------------
# Acceptance: chunked == monolithic, token for token
# ---------------------------------------------------------------------------

def _assert_chunked_matches_monolithic(cfg, params, **kw):
    mono = ServeEngine(cfg, params, max_seq=64, n_slots=2, **kw)
    want = {c.uid: c.tokens for c in mono.run(_straddle_reqs(cfg))}
    chk = ServeEngine(cfg, params, max_seq=64, n_slots=2,
                      prefill_chunk=CHUNK, **kw)
    got = {c.uid: c.tokens for c in chk.run(_straddle_reqs(cfg))}
    assert got == want
    return chk


def test_chunked_matches_monolithic_dense(setup):
    cfg, api, params, absorbed, pj = setup
    _assert_chunked_matches_monolithic(cfg, params)


def test_chunked_matches_monolithic_slab(setup):
    cfg, api, params, absorbed, pj = setup
    chk = _assert_chunked_matches_monolithic(
        cfg, absorbed, swan=_exact_swan(cfg), projections=pj)
    # chunk sizes bucket to powers of two and the slab read window buckets
    # over start+S: O(log chunk + log max_seq) executables
    if chk.prefill_cache_size != -1:
        assert chk.prefill_cache_size <= CHUNK.bit_length() + 1 + 7  # log2(64)+1


def test_chunked_matches_monolithic_paged(setup):
    cfg, api, params, absorbed, pj = setup
    chk = _assert_chunked_matches_monolithic(
        cfg, absorbed, swan=_exact_swan(cfg), projections=pj,
        paged=True, page_size=PAGE)
    assert chk.pool.live_pages == 0          # drained -> fully reclaimed
    chk.pool.check_consistent()


# ---------------------------------------------------------------------------
# Lossy compression: chunk boundaries change WHAT the prompt attends to
# (later chunks see the winnowed prefix, like decode does), so chunked and
# monolithic legitimately diverge — but the two LAYOUTS must agree exactly.
# ---------------------------------------------------------------------------

def _lossy_trace(cfg):
    return [
        Request(uid="long", tokens=_prompt(cfg, 40, seed=1),
                max_new_tokens=6, k=4),
        Request(uid="hot", tokens=_prompt(cfg, 5, seed=2),
                max_new_tokens=12, temperature=0.7, seed=9),
        Request(uid="mid", tokens=_prompt(cfg, 17, seed=3),
                max_new_tokens=8, arrival_step=3),
        Request(uid="tail", tokens=_prompt(cfg, 9, seed=4),
                max_new_tokens=4, arrival_step=6),
    ]


def test_chunked_paged_matches_chunked_slab_lossy_k(setup):
    """Mixed per-request k, a temperature lane and staggered arrivals at
    k_max < d_head: the paged chunked engine — including an over-committed
    pool that holds admissions for pages — reproduces the slab chunked
    engine token for token."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=8, buffer=BUF, mode="topk")
    kw = dict(swan=swan, projections=pj, max_seq=64, n_slots=2,
              prefill_chunk=CHUNK)
    slab = ServeEngine(cfg, absorbed, **kw)
    want = {c.uid: c.tokens for c in slab.run(_lossy_trace(cfg))}
    paged = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE, **kw)
    assert {c.uid: c.tokens for c in paged.run(_lossy_trace(cfg))} == want
    assert paged.pool.live_pages == 0
    paged.pool.check_consistent()
    over = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE,
                       n_pages=6, **kw)
    assert {c.uid: c.tokens for c in over.run(_lossy_trace(cfg))} == want
    over.pool.check_consistent()


def test_admission_hold_prevents_mid_prefill_exhaustion(setup):
    """Chunked paged admission maps pages per CHUNK but must HOLD the
    prompt's whole winnow need up front: without the hold, two same-step
    admissions both pass the free-page gate against the same pages and one
    prefill later dies in PagePoolExhausted mid-chunking — where the
    monolithic engine (mapping at admission) simply holds the second
    request back."""
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=8, buffer=BUF, mode="topk")
    reqs = lambda: [Request(uid=f"g{i}", tokens=_prompt(cfg, 36, seed=60 + i),
                            max_new_tokens=4) for i in range(2)]
    kw = dict(swan=swan, projections=pj, max_seq=64, n_slots=3,
              prefill_chunk=CHUNK)
    want = {c.uid: c.tokens
            for c in ServeEngine(cfg, absorbed, **kw).run(reqs())}
    # 3 usable pages; each request needs 2 at admission (+1 while decoding)
    eng = ServeEngine(cfg, absorbed, paged=True, page_size=PAGE, n_pages=4,
                      **kw)
    comps = eng.run(reqs())
    assert {c.uid: c.tokens for c in comps} == want
    by = {c.uid: c for c in comps}
    assert by["g1"].admitted_step > by["g0"].admitted_step   # held back
    assert eng.pool.live_pages == 0
    eng.pool.check_consistent()


# ---------------------------------------------------------------------------
# Interleaving: decode / retirement / backfill while a prefill is chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_interleaving_mid_prefill(setup, paged):
    """A slot retiring mid-prefill of another slot, and a backfill admission
    landing while that prefill is still chunking, must not perturb any
    sequence's tokens (vs the monolithic engine, at exact winnow)."""
    cfg, api, params, absorbed, pj = setup
    n_chunks_b = 48 // CHUNK
    trace = lambda: [
        Request(uid="a", tokens=_prompt(cfg, 6, seed=11), max_new_tokens=3),
        Request(uid="b", tokens=_prompt(cfg, 48, seed=12), max_new_tokens=6),
        Request(uid="c", tokens=_prompt(cfg, 7, seed=13), max_new_tokens=5),
    ]
    kw = dict(swan=_exact_swan(cfg), projections=pj, max_seq=64, n_slots=2)
    if paged:
        kw.update(paged=True, page_size=PAGE)
    want = {c.uid: c.tokens
            for c in ServeEngine(cfg, absorbed, **kw).run(trace())}
    chk = ServeEngine(cfg, absorbed, prefill_chunk=CHUNK, **kw)
    comps = chk.run(trace())
    assert {c.uid: c.tokens for c in comps} == want
    by = {c.uid: c for c in comps}
    # the interleavings actually happened: b's prefill spans n_chunks_b
    # engine steps from its admission; a retired and c backfilled within it
    assert by["a"].finished_step < by["b"].admitted_step + n_chunks_b
    assert by["c"].admitted_step <= by["a"].finished_step + 1
    assert by["c"].admitted_step < by["b"].admitted_step + n_chunks_b


# ---------------------------------------------------------------------------
# Executable bounds + validation
# ---------------------------------------------------------------------------

def test_prefill_executables_bounded_across_long_prompts(setup):
    """Distinct long prompt lengths must not grow the chunk-prefill
    executable count past O(log chunk + log max_seq): full chunks share
    one shape, remainders and the slab read window bucket to powers of
    two."""
    cfg, api, params, absorbed, pj = setup
    reqs = [Request(uid=f"l{i}", tokens=_prompt(cfg, n, seed=50 + i),
                    max_new_tokens=2)
            for i, n in enumerate([17, 22, 29, 35, 41, 46])]
    eng = ServeEngine(cfg, absorbed, swan=_exact_swan(cfg), projections=pj,
                      max_seq=64, n_slots=2, prefill_chunk=CHUNK)
    eng.run(reqs)
    if eng.prefill_cache_size != -1:
        assert eng.prefill_cache_size <= CHUNK.bit_length() + 1 + 7


def test_prefill_chunk_validation(setup):
    cfg, api, params, absorbed, pj = setup
    with pytest.raises(ValueError, match="power of two"):
        ServeEngine(cfg, params, max_seq=64, n_slots=1, prefill_chunk=6)
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(cfg, params, max_seq=96, n_slots=1, prefill_chunk=64)
