"""AdamW vs a straight-line numpy reference; schedule; clipping; decay mask;
state-dtype compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import OptimizerConfig
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               global_norm, init_opt_state, lr_schedule)


def _numpy_adamw(p, g, m, v, step, cfg):
    b1, b2 = cfg.betas
    gn = np.sqrt(sum((gi.astype(np.float64) ** 2).sum() for gi in g.values()))
    scale = min(1.0, cfg.grad_clip / max(gn, 1e-9))
    g = {k: gi * scale for k, gi in g.items()}
    lr_step = step  # schedule evaluated at pre-increment step
    warm = cfg.lr * (lr_step + 1) / cfg.warmup_steps
    prog = min(max((lr_step - cfg.warmup_steps) /
                   max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0), 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + np.cos(np.pi * prog))
    lr = warm if lr_step < cfg.warmup_steps else cfg.lr * cos
    out_p, out_m, out_v = {}, {}, {}
    t = step + 1
    for k in p:
        m_new = b1 * m[k] + (1 - b1) * g[k]
        v_new = b2 * v[k] + (1 - b2) * g[k] ** 2
        mh = m_new / (1 - b1 ** t)
        vh = v_new / (1 - b2 ** t)
        upd = mh / (np.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p[k] if p[k].ndim >= 2 else 0.0
        out_p[k] = p[k] - lr * (upd + decay)
        out_m[k], out_v[k] = m_new, v_new
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    cfg = OptimizerConfig(lr=1e-2, warmup_steps=2, decay_steps=10)
    p_np = {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "bias": rng.standard_normal((3,)).astype(np.float32)}
    p = {k: jnp.asarray(v) for k, v in p_np.items()}
    state = init_opt_state(p, cfg)
    m_np = {k: np.zeros_like(v) for k, v in p_np.items()}
    v_np = {k: np.zeros_like(v) for k, v in p_np.items()}
    for step in range(4):
        g_np = {k: rng.standard_normal(v.shape).astype(np.float32)
                for k, v in p_np.items()}
        g = {k: jnp.asarray(v) for k, v in g_np.items()}
        p, state, _ = adamw_update(p, g, state, cfg)
        p_np, m_np, v_np = _numpy_adamw(p_np, g_np, m_np, v_np, step, cfg)
    for k in p_np:
        np.testing.assert_allclose(np.asarray(p[k]), p_np[k], atol=1e-5,
                                   rtol=1e-4)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), cfg)) for s in
           [0, 5, 9, 10, 50, 99, 150]]
    assert lrs[0] < lrs[1] < lrs[2]            # warmup rising
    assert abs(lrs[3] - 1.0) < 0.05            # peak at end of warmup
    assert lrs[4] < lrs[3]                     # decaying
    assert abs(lrs[6] - 0.1) < 1e-5            # floor


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_no_decay_on_norms_and_biases():
    cfg = OptimizerConfig(lr=1e-2, weight_decay=1e6, warmup_steps=1,
                          decay_steps=10)  # huge decay to expose masking
    p = {"w": jnp.ones((2, 2)), "scale": jnp.ones((4,))}
    g = {"w": jnp.zeros((2, 2)), "scale": jnp.zeros((4,))}
    state = init_opt_state(p, cfg)
    new_p, _, _ = adamw_update(p, g, state, cfg)
    assert float(jnp.max(jnp.abs(new_p["scale"] - 1.0))) < 1e-6  # untouched
    assert float(jnp.max(jnp.abs(new_p["w"] - 1.0))) > 1.0       # decayed


def test_bf16_state_compression():
    cfg = OptimizerConfig(state_dtype="bfloat16")
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = init_opt_state(p, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    _, state, _ = adamw_update(p, g, state, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(state["m"]["w"].astype(jnp.float32) - 0.01))) < 1e-3
