"""Elastic checkpoint resharding + whisper cross-attention SWAN extension
+ int8 grad sync on a real multi-device mesh (subprocess)."""
import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_loop import calibrate_swan


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_mesh
import numpy as np

# save on a (2,4) mesh layout, restore onto (4,2) — elastic re-mesh
tree = {"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
        "b": jnp.arange(32, dtype=jnp.float32)}
mesh1 = make_mesh((2, 4), ("data", "model"))
sh1 = {"w": NamedSharding(mesh1, P("data", "model")),
       "b": NamedSharding(mesh1, P("model"))}
tree1 = jax.device_put(tree, sh1)

ck = Checkpointer("/tmp/repro_elastic_ckpt", keep=1)
ck.save(1, tree1)

mesh2 = make_mesh((4, 2), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("model", "data")),
       "b": NamedSharding(mesh2, P(None))}
tree2 = ck.restore(1, tree, shardings=sh2)
ok_val = bool(jnp.all(tree2["w"] == tree["w"]))
ok_shard = tree2["w"].sharding.spec == P("model", "data")
print(json.dumps({"ok_val": ok_val, "ok_shard": bool(ok_shard)}))
"""


def test_elastic_reshard_restore():
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-1500:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok_val"] and rec["ok_shard"], rec


def test_whisper_cross_attn_swan_extension():
    """compress_cross_attn winnows the static cross-attention cache; at
    full retention the output must match the uncompressed cross cache."""
    cfg = get_smoke_config("whisper-small").replace(dtype="float32",
                                                    param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 10)
    pj = calibrate_swan(api, cfg, params, batch)
    absorbed = api.absorb(params, cfg, pj)

    def serve(compress_cross, k_max):
        swan = SwanConfig(k_max=k_max, buffer=4, mode="topk",
                          compress_cross_attn=compress_cross)
        st = api.init_serve_state(cfg, swan, 2, 24)
        lg, st = api.prefill(absorbed, cfg, batch, st, swan, pj)
        tok = jnp.argmax(lg[:, -1], -1)
        lg2, st = api.decode_step(absorbed, cfg, tok, 10, st, swan, pj)
        return lg2

    full = serve(False, cfg.d_head)
    full_cc = serve(True, cfg.d_head)      # full retention: lossless
    np.testing.assert_allclose(np.asarray(full), np.asarray(full_cc),
                               atol=2e-4, rtol=1e-3)
    comp = serve(True, cfg.d_head // 2)    # compressed: runs, no NaN
    assert not bool(jnp.any(jnp.isnan(comp)))


_INT8_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.runtime.grad_compress import dp_int8_allreduce
from repro.sharding.api import shard_map_compat

mesh = make_mesh((4,), ("data",))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))  # per-shard rows

def f(g):
    return dp_int8_allreduce({"w": g}, "data")["w"]

out = jax.jit(shard_map_compat(f, mesh, (P("data"),), P("data")))(g)
# every shard's output row == mean of all rows (up to int8 error)
mean = g.mean(axis=0)
err = float(jnp.max(jnp.abs(out - mean[None])))
bound = float(jnp.max(jnp.abs(g))) / 127.0
print(json.dumps({"err": err, "bound": bound}))
"""


def test_int8_allreduce_multidevice():
    out = subprocess.run([sys.executable, "-c", _INT8_DP_SCRIPT],
                         capture_output=True, text=True, timeout=300,
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr[-1500:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] <= rec["bound"] + 1e-6, rec
