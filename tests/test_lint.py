"""swanlint: every Layer 1 rule fires on a seeded violation, stays quiet
on its negative twin, and honors (only) justified suppressions; the Layer
2 check helpers fail on seeded compiled artifacts; and the repo itself is
clean vs the committed baseline (the CI --check contract)."""
import os
import textwrap

import pytest

from repro.analysis.lint import (DEFAULT_BASELINE, load_baseline,
                                 make_report, new_findings, run_lint)
from repro.analysis.lint.audit import (collective_check, count_check,
                                       kernel_precheck_checks,
                                       transfer_check)
from repro.analysis.lint.rules import lint_paths, lint_source

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint(src, rel="src/repro/runtime/engine.py"):
    return lint_source(textwrap.dedent(src), rel)


def _active(findings, rule=None):
    return [f for f in findings
            if not f.suppressed and (rule is None or f.rule == rule)]


# ---------------------------------------------------------------------------
# SWAN101 — JAX floor
# ---------------------------------------------------------------------------

FLOOR_SRC = """
    import jax
    from jax.sharding import AxisType

    def wire(f, mesh):
        return jax.shard_map(f, mesh=mesh)
"""


def test_floor_flags_post_floor_apis():
    hits = _active(_lint(FLOOR_SRC), "SWAN101")
    assert len(hits) == 2                      # AxisType import + shard_map
    assert any("jax.shard_map" in f.message for f in hits)


def test_floor_allows_shim_modules_and_floor_apis():
    assert not _active(_lint(FLOOR_SRC, rel="src/repro/sharding/api.py"),
                       "SWAN101")
    ok = "import jax\nmesh = jax.make_mesh((2,), ('data',))\n"
    assert not _active(_lint(ok), "SWAN101")


# ---------------------------------------------------------------------------
# SWAN102 — host sync on the serve hot path
# ---------------------------------------------------------------------------

HOT_SRC = """
    import jax
    import numpy as np

    class Eng:
        def __init__(self):
            self._decode = jax.jit(lambda x: x)

        def step(self):
            logits = self._decode(1)
            x = float(logits)                     # tainted conversion
            self._decode(1).block_until_ready()   # sync primitive
            return self._fetch(logits)

        def _fetch(self, logits):
            return np.asarray(logits)             # taint crosses the call

        def _lane_tokens(self, logits):
            return np.asarray(logits)             # designed fetch point

        def offline(self, logits):
            return float(logits)                  # not reachable from step
"""


def test_host_sync_flags_reachable_syncs_only():
    hits = _active(_lint(HOT_SRC), "SWAN102")
    lines = {f.line for f in hits}
    assert len(hits) == 3, hits
    assert lines == {11, 12, 16}                 # float, sync, _fetch


def test_host_sync_untainted_conversion_ok():
    src = """
        import jax
        import numpy as np

        class Eng:
            def __init__(self):
                self._decode = jax.jit(lambda x: x)
                self.slot_pos = np.zeros((4,), np.int32)

            def step(self):
                i = int(self.slot_pos[0])     # host numpy, never tainted
                self._decode(i)
    """
    assert not _active(_lint(src), "SWAN102")


def test_host_sync_scoped_to_runtime():
    assert not _active(_lint(HOT_SRC, rel="src/repro/launch/driver.py"),
                       "SWAN102")


# ---------------------------------------------------------------------------
# SWAN103 — shape bucketing
# ---------------------------------------------------------------------------

BUCKET_SRC = """
    import numpy as np

    def build_decode(n):
        return np.zeros((4, 48), np.int32)

    def init_params(n):
        return np.zeros((4, 48), np.float32)   # not a dispatch builder
"""


def test_bucketing_flags_non_pow2_in_dispatch_builders():
    hits = _active(_lint(BUCKET_SRC), "SWAN103")
    assert len(hits) == 1 and "48" in hits[0].message


def test_bucketing_pow2_and_scope_negatives():
    ok = "import numpy as np\ndef build_decode(n):\n" \
         "    return np.zeros((4, 64), np.int32)\n"
    assert not _active(_lint(ok), "SWAN103")
    assert not _active(_lint(BUCKET_SRC, rel="src/repro/optim/adamw.py"),
                       "SWAN103")


# ---------------------------------------------------------------------------
# SWAN104 — spec completeness (cross-module)
# ---------------------------------------------------------------------------

def _spec_fixture(tmp_path, cache_src):
    (tmp_path / "src/repro/sharding").mkdir(parents=True)
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/sharding/serve_specs.py").write_text(
        'KNOWN_LEAF_NAMES = ("k", "v")\n')
    (tmp_path / "src/repro/core/hybrid_cache.py").write_text(
        textwrap.dedent(cache_src))
    return lint_paths(str(tmp_path), ["src/repro/sharding/serve_specs.py",
                                      "src/repro/core/hybrid_cache.py"])


def test_spec_completeness_flags_rogue_leaf(tmp_path):
    hits = _active(_spec_fixture(tmp_path, """
        import jax.numpy as jnp

        def init_cache(L):
            d = {"k": jnp.zeros((L,)), "rogue": jnp.zeros((L,))}
            d["late"] = jnp.zeros((L,))
            return d
    """), "SWAN104")
    assert {f.message.split("'")[1] for f in hits} == {"rogue", "late"}


def test_spec_completeness_known_leaves_ok(tmp_path):
    assert not _active(_spec_fixture(tmp_path, """
        import jax.numpy as jnp

        def init_cache(L):
            return {"k": jnp.zeros((L,)), "v": jnp.zeros((L,))}
    """), "SWAN104")


def test_spec_completeness_suppressible(tmp_path):
    fs = _spec_fixture(tmp_path, """
        import jax.numpy as jnp

        def init_cache(L):
            return {
                # swanlint: disable=SWAN104 -- host-only scratch, never
                # crosses shard_map
                "rogue": jnp.zeros((L,)),
            }
    """)
    hits = [f for f in fs if f.rule == "SWAN104"]
    assert hits and all(f.suppressed for f in hits)


# ---------------------------------------------------------------------------
# SWAN105 — obs hygiene
# ---------------------------------------------------------------------------

OBS_SRC = """
    _step_counters = {}

    limits = {}     # not metric-named: fine
"""


def test_obs_flags_module_level_metric_dicts():
    hits = _active(_lint(OBS_SRC), "SWAN105")
    assert len(hits) == 1 and "_step_counters" in hits[0].message


def test_obs_allows_registry_module():
    assert not _active(_lint(OBS_SRC, rel="src/repro/obs/metrics.py"),
                       "SWAN105")


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

def test_justified_suppression_covers_whole_statement():
    src = """
        import jax

        class Eng:
            def __init__(self):
                self._decode = jax.jit(lambda x: x)

            def step(self):
                out = self._decode(1)
                # swanlint: disable=SWAN102 -- test fixture: measured sync
                return [float(out),
                        float(out)]
    """
    fs = _lint(src)
    hits = [f for f in fs if f.rule == "SWAN102"]
    assert len(hits) == 2 and all(f.suppressed for f in hits)
    assert all("measured sync" in f.justification for f in hits)


def test_unjustified_suppression_is_a_finding_and_does_not_suppress():
    src = """
        import jax

        class Eng:
            def __init__(self):
                self._decode = jax.jit(lambda x: x)

            def step(self):
                out = self._decode(1)
                return float(out)  # swanlint: disable=SWAN102
    """
    fs = _lint(src)
    assert _active(fs, "SWAN100")
    assert _active(fs, "SWAN102")              # NOT suppressed


def test_unknown_rule_id_flagged():
    fs = _lint("x = 1  # swanlint: disable=SWAN999 -- nope\n")
    assert _active(fs, "SWAN100")


# ---------------------------------------------------------------------------
# Layer 2 check helpers on seeded artifacts
# ---------------------------------------------------------------------------

DIRTY_HLO = """\
HloModule dirty

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128] parameter(0)
  %ar-start = (f32[8,128], f32[8,128]) all-reduce-start(%p0), replica_groups={}
  %cp = f32[8,128]{1,0:S(5)} copy(%p0)
  %tok = token[] after-all()
  %inf = ((f32[4]), token[]) infeed(%tok)
  ROOT %out = f32[8,128] add(%cp, %p0)
}
"""

CLEAN_HLO = """\
HloModule clean

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128] parameter(0)
  ROOT %out = f32[8,128] add(%p0, %p0)
}
"""


def test_transfer_check_fails_on_seeded_host_traffic():
    c = transfer_check(DIRTY_HLO, "seeded")
    assert c.status == "fail"
    assert "host transfer" in c.detail and "unmatched" in c.detail
    assert transfer_check(CLEAN_HLO, "clean").status == "pass"


def test_collective_check_fails_on_undeclared_collective():
    assert collective_check(DIRTY_HLO, "seeded").status == "fail"
    assert collective_check(CLEAN_HLO, "clean").status == "pass"
    assert collective_check(DIRTY_HLO, "ok",
                            allowed=("all-reduce",)).status == "pass"


def test_count_check_bounds():
    assert count_check("x", 5, 3).status == "fail"
    assert count_check("x", 3, 3).status == "pass"
    assert count_check("x", -1, 3).status == "skip"


def test_kernel_precheck_fails_on_seeded_shapes():
    from repro.kernels.flash_prefill.flash_prefill import precheck as fp
    from repro.kernels.swan_decode.swan_decode import precheck as sd
    bad = sd(B=1, Kv=4, G=8, dh=128, S=1000, k_max=256, b=32)
    assert any("divisible" in e for e in bad["errors"])
    assert any("k_max" in e for e in bad["errors"])
    tight = sd(B=1, Kv=4, G=8, dh=128, S=1024, k_max=64, b=32,
               vmem_budget=1024)
    assert any("VMEM" in e for e in tight["errors"])
    assert not sd(B=1, Kv=4, G=8, dh=128, S=1024, k_max=64, b=32)["errors"]
    assert any("Kv" in e or "H=" in e
               for e in fp(B=1, H=8, Kv=3, Sq=512, Sk=512, dh=128)["errors"])
    assert not fp(B=1, H=8, Kv=4, Sq=512, Sk=512, dh=128)["errors"]


def test_kernel_precheck_checks_smoke_config():
    from repro.configs import SwanConfig, get_smoke_config
    cfg = get_smoke_config("llama3-8b")
    checks = kernel_precheck_checks(
        cfg, SwanConfig(k_max=cfg.d_head, buffer=4, mode="topk"), 64)
    assert all(c.status == "pass" for c in checks), checks


# ---------------------------------------------------------------------------
# The repo gate itself
# ---------------------------------------------------------------------------

def test_repo_is_clean_vs_baseline():
    findings = run_lint(REPO)
    assert not _active(findings), [f.to_json() for f in _active(findings)]
    baseline = load_baseline(os.path.join(REPO, DEFAULT_BASELINE))
    assert baseline is not None, "bench_out/LINT_BASELINE.json missing"
    assert new_findings(findings, baseline) == []


def test_report_counts_and_fingerprint_stability():
    findings = run_lint(REPO)
    rep = make_report(findings)
    assert rep["counts"]["total"] == len(findings)
    assert rep["counts"]["active"] == 0
    # fingerprints are line-number-free: shifting a finding down a line
    # must not mint a new identity
    src = ("import jax\n\nclass E:\n    def __init__(self):\n"
           "        self._d = jax.jit(lambda x: x)\n"
           "    def step(self):\n        return float(self._d(1))\n")
    f1 = _active(lint_source(src, "src/repro/runtime/x.py"), "SWAN102")
    shifted = src.replace("import jax\n", "import jax\n# pad\n")
    f2 = _active(lint_source(shifted, "src/repro/runtime/x.py"), "SWAN102")
    assert f1[0].fingerprint == f2[0].fingerprint
    assert f1[0].line != f2[0].line
