import os

# Smoke tests / benches must see the REAL device count (1 CPU) — the 512-way
# dry-run flag is set only inside repro.launch.dryrun (per spec).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
