"""SWAN hybrid-cache attention: single-shot vs oracle, modes, quantization,
runtime tunability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.core import hybrid_cache as hc
from repro.core import swan_attention as swa


def _filled_cache(cfg, swan, B=2, S=32, n_tok=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kh = jax.random.normal(key, (B, n_tok, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.PRNGKey(seed + 1),
                           (B, n_tok, cfg.n_kv_heads, cfg.d_head))
    cache = hc.init_swan_cache(cfg, swan, B, S)
    return hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh), n_tok - 1


@pytest.mark.parametrize("mode", ["topk", "truncate"])
@pytest.mark.parametrize("quantize", [False, True])
def test_matches_reference(mode, quantize):
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=4, mode=mode, quantize=quantize)
    cache, pos = _filled_cache(cfg, swan)
    q = jax.random.normal(jax.random.PRNGKey(7),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    o1 = swa.swan_decode_attention(q, cache, swan, cfg, pos)
    o2 = swa.swan_decode_attention_reference(q, cache, swan, cfg, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_empty_sparse_region():
    """pos < buffer: attention over buffer only, no NaN from empty sparse."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=16, mode="topk")
    cache, _ = _filled_cache(cfg, swan, n_tok=5)
    q = jax.random.normal(jax.random.PRNGKey(3),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    o = swa.swan_decode_attention(q, cache, swan, cfg, 4)
    ref = swa.swan_decode_attention_reference(q, cache, swan, cfg, 4)
    assert not bool(jnp.any(jnp.isnan(o)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=1e-5)


def test_runtime_k_tunability_monotone_error():
    """Smaller runtime k_active -> larger deviation from the exact output
    (graceful, monotone-ish degradation — paper's tunability claim)."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    q = jax.random.normal(jax.random.PRNGKey(11),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    errs = []
    exact = None
    for k_act in [16, 8, 4, 2]:
        swan = SwanConfig(k_max=16, buffer=4, mode="topk",
                          k_key=k_act, k_value=k_act)
        cache, pos = _filled_cache(cfg, swan, seed=5)
        o = swa.swan_decode_attention(q, cache, swan, cfg, pos)
        if exact is None:   # k_act = k_max = d_head = exact
            exact = o
        errs.append(float(jnp.max(jnp.abs(o - exact))))
    assert errs[0] == 0.0
    assert errs[-1] > errs[1]


def test_truncate_uses_leading_dims_only():
    """In truncate mode the output must be invariant to q's tail dims for
    the sparse part (structural property of the low-rank dot)."""
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=4, buffer=2, mode="truncate")
    cache, pos = _filled_cache(cfg, swan, n_tok=12, seed=2)
    # zero the buffer so only the sparse path contributes
    cache["buf_pos"] = jnp.full_like(cache["buf_pos"], -1)
    q = jax.random.normal(jax.random.PRNGKey(0),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    q2 = q.at[..., swan.k_max:].add(1.0)   # perturb tail dims
    o1 = swa.swan_decode_attention(q, cache, swan, cfg, pos)
    o2 = swa.swan_decode_attention(q2, cache, swan, cfg, pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_sharded_split_s_matches_plain():
    """shard_map split-S on a 1x1 mesh must equal the plain path (the stat
    merge algebra is exercised even with a single shard)."""
    from repro.launch.mesh import make_mesh
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    cache, pos = _filled_cache(cfg, swan)
    q = jax.random.normal(jax.random.PRNGKey(7),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    mesh = make_mesh((1,), ("model",))
    o_plain = swa.swan_decode_attention(q, cache, swan, cfg, pos)
    o_shard = swa.swan_decode_attention(q, cache, swan, cfg, pos,
                                        mesh=mesh, seq_axis="model")
    np.testing.assert_allclose(np.asarray(o_plain), np.asarray(o_shard),
                               atol=1e-6)
