"""Checkpointer: atomic roundtrip, corruption detection, keep-k GC, async
writes, bit-exact training resume, structural validation."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import OptimizerConfig, TrainConfig, get_smoke_config
from repro.runtime.train_loop import Trainer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(7, dtype=jnp.int32),
                       "c": jax.random.normal(k, (2,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    ck.save(3, t)
    out = ck.restore(3, jax.tree_util.tree_map(np.asarray, t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crc_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t)
    d = os.path.join(str(tmp_path), "step_00000001")
    fn = os.path.join(d, "arr_00000.bin")
    with open(fn, "r+b") as f:
        f.seek(0)
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(IOError, match="corruption"):
        ck.restore(1, t)


def test_keep_k_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree())
    assert ck.all_steps() == [3, 4]


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ck.restore(1, {"a": jnp.zeros((4,))})


def test_tmp_litter_cleaned(tmp_path):
    ck = Checkpointer(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    ck.save(1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


def test_training_resume_bit_exact(tmp_path):
    """Train 8 steps straight vs 4 + checkpoint + fresh Trainer + 4 more:
    identical final loss (data is a pure function of step; state round-trips
    losslessly)."""
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    base = dict(model=cfg, seq_len=16, global_batch=4,
                optimizer=OptimizerConfig(lr=1e-2, warmup_steps=2,
                                          decay_steps=8),
                log_every=1, keep_checkpoints=5, async_checkpoint=False)

    tc1 = TrainConfig(steps=8, checkpoint_dir=str(tmp_path / "a"),
                      checkpoint_every=100, **base)
    out1 = Trainer(tc1, jit=True, donate=False).run()

    tc2 = TrainConfig(steps=8, checkpoint_dir=str(tmp_path / "b"),
                      checkpoint_every=4, **base)
    Trainer(tc2, jit=True, donate=False).run(steps=4)
    out2 = Trainer(tc2, jit=True, donate=False).run()   # resumes at 4
    assert out2["step"] == 8
    np.testing.assert_allclose(out1["log"][-1]["loss"],
                               out2["log"][-1]["loss"], atol=1e-6)
