"""Executable-family warmup (``repro.runtime.warmup``) and the overlapped
decode token fetch.

Contracts locked in here:

* ``ServeEngine.warmup()`` pre-compiles the engine's complete executable
  family — a randomized post-warmup workload (mixed prompt lengths,
  per-request k, greedy and temperature lanes, chunked prefill) triggers
  ZERO new XLA compiles on both the slab and the paged engine, with a
  stable ``executable_census()``.  Compiles are counted with the
  process-global ``repro.obs.compile_events`` listener, which also sees
  eager one-off executables the jit caches cannot.
* warmup is idempotent, token-transparent (warmed == never-warmed output)
  and covers at least the statically enumerated expected family.
* ``async_fetch=True`` overlaps the decode token transfer with host
  scheduling and is token-, step- and dispatch-identical to sync.
* decode/prefill/insert donate the serve state: after a step the previous
  state's device buffers are deleted, so steady-state decode allocates no
  second cache copy.
* pool growth on the paged engine re-warms the refreshed executable
  family (the pool leaf shape changes stale every state-keyed
  executable).

Engines warm a deliberately small family (tiny model, short ``max_seq``,
``max_prompt_len`` trim) and are shared module-wide to keep runtime sane.
"""
import jax
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.obs import compile_events
from repro.runtime.serve_engine import Request, ServeEngine
from repro.runtime.serve_loop import calibrate_swan

MAX_SEQ = 32
N_SLOTS = 2
CHUNK = 8
PREFILL_SLOTS = 2
PROMPT_CAP = 8
PAGE_SIZE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, dtype="float32", param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    pj = calibrate_swan(api, cfg, params, make_batch(cfg, 2, 24, seed=3))
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    return cfg, absorbed, swan, pj


def _engine(setup, **kw):
    cfg, absorbed, swan, pj = setup
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("n_slots", N_SLOTS)
    return ServeEngine(cfg, absorbed, swan=swan, projections=pj, **kw)


def _chunked(setup, **kw):
    return _engine(setup, prefill_chunk=CHUNK, prefill_slots=PREFILL_SLOTS,
                   **kw)


def _workload(cfg, seed=0, n=6):
    """Randomized mixed workload with every prompt prebuilt — building a
    prompt via make_batch traces eager slice ops, which must happen BEFORE
    any compile-count snapshot."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.randint(1, PROMPT_CAP + 1))
        toks = [int(t) for t in
                make_batch(cfg, 1, plen, seed=300 + i)["tokens"][0]]
        reqs.append(Request(
            uid=f"req{i}", tokens=toks,
            max_new_tokens=int(rng.randint(2, 5)),
            temperature=float(rng.choice([0.0, 0.0, 0.7, 1.3])),
            seed=int(rng.randint(0, 2**31 - 1)),
            k=[None, 4, 8][int(rng.randint(0, 3))]))
    return reqs


def _clone(reqs):
    return [Request(uid=r.uid, tokens=list(r.tokens),
                    max_new_tokens=r.max_new_tokens,
                    temperature=r.temperature, seed=r.seed, k=r.k)
            for r in reqs]


@pytest.fixture(scope="module")
def warmed_slab(setup):
    eng = _chunked(setup)
    report = eng.warmup(max_prompt_len=PROMPT_CAP)
    return eng, report


@pytest.fixture(scope="module")
def warmed_paged(setup):
    eng = _chunked(setup, paged=True, page_size=PAGE_SIZE)
    report = eng.warmup(max_prompt_len=PROMPT_CAP)
    return eng, report


# ---------------------------------------------------------------------------
# Census + family coverage
# ---------------------------------------------------------------------------

def test_census_requires_jit(setup):
    eng = _engine(setup, jit=False)
    with pytest.raises(RuntimeError, match="jit"):
        eng.executable_census()
    # the cache-size properties degrade to 0 (not a silent -1)
    assert eng.decode_cache_size == 0
    assert eng.prefill_cache_size == 0


def test_warmup_requires_jit(setup):
    eng = _engine(setup, jit=False)
    with pytest.raises(RuntimeError, match="jit"):
        eng.warmup()


def test_warmup_covers_expected_family(warmed_slab):
    eng, report = warmed_slab
    census, expected = report["census"], report["expected"]
    assert census["decode"] >= expected["decode"]
    assert census["prefill"] >= expected["prefill"]
    assert census["insert"] >= expected["insert"]
    for bucket, n in expected["chunk"].items():
        assert census["chunk"].get(bucket, 0) >= n, (bucket, census["chunk"])
    assert report["compiles"] > 0
    assert report["warmup_ms"] > 0
    # warmup stamps its gauge and phase-labelled compile counter
    assert eng.metrics.value("serve_warmup_ms") > 0
    assert eng.metrics.value("serve_compile_total", phase="warmup",
                             kind="chunk") > 0


def test_warmup_covers_paged_family(warmed_paged):
    eng, report = warmed_paged
    census, expected = report["census"], report["expected"]
    assert census["decode"] >= expected["decode"]
    # paged decode buckets by page-table prefix width (pow2 family)
    assert expected["decode"] >= 2
    for bucket, n in expected["chunk"].items():
        assert census["chunk"].get(bucket, 0) >= n


def test_warmup_idempotent(warmed_slab, warmed_paged):
    for eng, _ in (warmed_slab, warmed_paged):
        census0 = eng.executable_census()
        rep2 = eng.warmup(max_prompt_len=PROMPT_CAP)
        assert rep2["compiles"] == 0, [
            r for r in rep2["items"] if r["compiles"]]
        assert eng.executable_census() == census0


# ---------------------------------------------------------------------------
# Zero steady-state compiles
# ---------------------------------------------------------------------------

def _assert_zero_compile_workload(eng, cfg):
    reqs = _workload(cfg)
    census0 = eng.executable_census()
    c0 = compile_events.total()
    comps = eng.run(reqs)
    assert compile_events.total() - c0 == 0
    assert eng.executable_census() == census0
    assert len(comps) == len(reqs)
    return comps


def test_zero_compiles_after_warmup_slab(warmed_slab, setup):
    cfg = setup[0]
    comps = _assert_zero_compile_workload(warmed_slab[0], cfg)
    # warmup is token-transparent: a never-warmed engine on the same
    # workload produces identical output
    fresh = _chunked(setup)
    fresh_comps = fresh.run(_clone(_workload(cfg)))
    assert ({c.uid: c.tokens for c in comps}
            == {c.uid: c.tokens for c in fresh_comps})


def test_zero_compiles_after_warmup_paged(warmed_paged, setup):
    _assert_zero_compile_workload(warmed_paged[0], setup[0])


# ---------------------------------------------------------------------------
# Async token fetch
# ---------------------------------------------------------------------------

def test_async_fetch_identical_to_sync(setup):
    cfg = setup[0]
    reqs = _workload(cfg, seed=7)
    e_sync = _chunked(setup)
    e_async = _chunked(setup, async_fetch=True)
    c1 = e_sync.run(_clone(reqs))
    c2 = e_async.run(_clone(reqs))
    assert e_async.done and e_async._pending is None
    assert ({c.uid: c.tokens for c in c1}
            == {c.uid: c.tokens for c in c2})
    assert ({c.uid: (c.admitted_step, c.first_token_step, c.finished_step)
             for c in c1}
            == {c.uid: (c.admitted_step, c.first_token_step, c.finished_step)
                for c in c2})
    # the overlap changes WHEN tokens are resolved, not what is dispatched
    assert e_sync.dispatches == e_async.dispatches


def test_async_fetch_greedy_only(setup):
    cfg = setup[0]
    reqs = [Request(uid=f"g{i}",
                    tokens=[int(t) for t in
                            make_batch(cfg, 1, 4 + i, seed=i)["tokens"][0]],
                    max_new_tokens=3) for i in range(3)]
    e_sync = _engine(setup)
    e_async = _engine(setup, async_fetch=True)
    t1 = {c.uid: c.tokens for c in e_sync.run(_clone(reqs))}
    t2 = {c.uid: c.tokens for c in e_async.run(_clone(reqs))}
    assert t1 == t2


# ---------------------------------------------------------------------------
# Buffer donation
# ---------------------------------------------------------------------------

def _backend_donates():
    x = jax.numpy.ones((4,))
    jax.jit(lambda a: a + 1, donate_argnums=0)(x)
    return x.is_deleted()


@pytest.mark.parametrize("paged", [False, True])
def test_decode_donates_all_state_leaves(setup, paged):
    if not _backend_donates():
        pytest.skip("backend does not honour buffer donation")
    cfg = setup[0]
    kw = dict(paged=True, page_size=PAGE_SIZE) if paged else {}
    eng = _engine(setup, **kw)
    eng.submit(Request(uid="a", tokens=_workload(cfg)[0].tokens,
                       max_new_tokens=4))
    eng.step()                      # admission (prefill + insert)
    leaves = jax.tree_util.tree_leaves(eng.state)
    eng.step()                      # pure decode: state donated in full
    assert all(leaf.is_deleted() for leaf in leaves), (
        "decode left stale state buffers alive — a donation leaf was missed")


def test_chunked_prefill_donates_state(setup):
    if not _backend_donates():
        pytest.skip("backend does not honour buffer donation")
    cfg = setup[0]
    eng = _chunked(setup)
    long_prompt = [int(t) for t in
                   make_batch(cfg, 1, 3 * CHUNK, seed=11)["tokens"][0]]
    eng.submit(Request(uid="a", tokens=long_prompt, max_new_tokens=2))
    eng.step()                      # first chunk lands
    leaves = jax.tree_util.tree_leaves(eng.state)
    eng.step()                      # next chunk: state donated through
    assert all(leaf.is_deleted() for leaf in leaves)


# ---------------------------------------------------------------------------
# Pool growth re-warms
# ---------------------------------------------------------------------------

def test_pool_growth_rewarms_family(setup):
    cfg = setup[0]
    # tiny pool: the workload's generated tokens force at least one grow
    eng = _engine(setup, paged=True, page_size=4, n_pages=4, pool_grow=True)
    eng.warmup(max_prompt_len=PROMPT_CAP)
    assert eng._warmed
    reqs = [Request(uid=f"r{i}",
                    tokens=[int(t) for t in
                            make_batch(cfg, 1, 8, seed=40 + i)["tokens"][0]],
                    max_new_tokens=8, k=4) for i in range(2)]
    comps = eng.run(reqs)
    assert len(comps) == 2
    census = eng.executable_census()
    assert census["pool_grow_total"] >= 1
    # the post-growth re-warm restored full coverage: a same-shape rerun
    # compiles nothing even though every state-keyed executable was staled
    c0 = compile_events.total()
    eng.run([Request(uid="again", tokens=list(reqs[0].tokens),
                     max_new_tokens=8, k=4)])
    assert compile_events.total() - c0 == 0
