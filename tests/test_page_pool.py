"""Page-pool allocator invariants: random alloc/free/reuse schedules never
alias two live sequences, exhaustion raises cleanly without corrupting
state, and freed pages are reusable.  The randomized schedule runs under
hypothesis when available (CI: requirements-dev.txt) and over a fixed set
of numpy-seeded schedules otherwise."""
import numpy as np
import pytest

from repro.runtime.page_pool import TRASH_PAGE, PagePool, PagePoolExhausted

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _assert_no_aliasing(pool: PagePool) -> None:
    """Independent re-check (not via check_consistent): every non-trash
    physical page appears in at most one slot's table, at most once."""
    live = pool.table[pool.table != TRASH_PAGE]
    assert live.size == len(set(live.tolist()))
    assert TRASH_PAGE not in live


def _run_schedule(n_pages, n_slots, ops):
    page_size = 8
    pages_per_seq = 6
    pool = PagePool(n_pages, pages_per_seq, n_slots, page_size)
    # host-side mirror of what each slot should have mapped
    mirror = {s: 0 for s in range(n_slots)}
    for op, slot, tokens in ops:
        slot %= n_slots
        if op == "free":
            freed = pool.free_slot(slot)
            assert freed == mirror[slot]
            mirror[slot] = 0
        else:
            need = -(-min(tokens, page_size * pages_per_seq) // page_size)
            try:
                pool.ensure(slot, min(tokens, page_size * pages_per_seq))
                mirror[slot] = max(mirror[slot], need)
            except PagePoolExhausted:
                # exhaustion must leave the pool fully consistent — the
                # pages granted before running dry stay owned
                mirror[slot] = int(pool.n_mapped[slot])
        _assert_no_aliasing(pool)
        pool.check_consistent()
        assert pool.live_pages == sum(mirror.values())
        assert pool.live_pages + pool.free_pages == n_pages - 1


if HAVE_HYPOTHESIS:
    @given(
        n_pages=st.integers(2, 24),
        n_slots=st.integers(1, 5),
        ops=st.lists(
            st.tuples(st.sampled_from(["ensure", "free"]),
                      st.integers(0, 4),          # slot (mod n_slots)
                      st.integers(0, 80)),        # tokens
            max_size=60),
    )
    @settings(max_examples=60, deadline=None)
    def test_random_schedule_never_aliases(n_pages, n_slots, ops):
        _run_schedule(n_pages, n_slots, ops)
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_random_schedule_never_aliases(seed):
        rng = np.random.default_rng(seed)
        ops = [(("ensure", "free")[int(rng.integers(4)) == 0],
                int(rng.integers(5)), int(rng.integers(81)))
               for _ in range(60)]
        _run_schedule(int(rng.integers(2, 25)), int(rng.integers(1, 6)), ops)


def test_exhaustion_raises_cleanly():
    pool = PagePool(4, 8, 2, 16)          # 3 usable pages
    pool.ensure(0, 48)                    # takes all 3
    with pytest.raises(PagePoolExhausted):
        pool.ensure(1, 16)
    pool.check_consistent()               # failed alloc corrupted nothing
    assert pool.live_pages == 3 and pool.free_pages == 0
    pool.free_slot(0)
    pool.ensure(1, 16)                    # freed pages immediately reusable
    assert pool.live_pages == 1


def test_freed_pages_reused_without_aliasing():
    pool = PagePool(6, 4, 3, 8)
    pool.ensure(0, 16)
    first = set(pool.table[0, :2].tolist())
    pool.ensure(1, 16)
    pool.free_slot(0)
    pool.ensure(2, 16)                    # backfill grabs slot-0's pages
    assert set(pool.table[2, :2].tolist()) == first
    _assert_no_aliasing(pool)
    pool.check_consistent()


def test_trash_page_never_allocated():
    pool = PagePool(5, 4, 1, 8)
    pool.ensure(0, 32)                    # all 4 usable pages
    assert TRASH_PAGE not in pool.table[0, :4].tolist()
    # unmapped tail entries all point at the trash page
    pool2 = PagePool(5, 4, 2, 8)
    pool2.ensure(0, 8)
    assert (pool2.table[0, 1:] == TRASH_PAGE).all()
    assert (pool2.table[1, :] == TRASH_PAGE).all()


def test_over_capacity_request_rejected():
    pool = PagePool(16, 2, 1, 8)
    with pytest.raises(ValueError, match="pages_per_seq"):
        pool.ensure(0, 100)


def test_grow_extends_pool_without_moving_pages():
    """Growth appends fresh pages to the BACK of each free list (warm
    just-freed pages still go out first) and never invalidates existing
    table entries."""
    pool = PagePool(4, 8, 2, 16)          # 3 usable pages
    pool.ensure(0, 48)                    # takes all 3
    mapped = pool.table[0, :3].copy()
    with pytest.raises(PagePoolExhausted):
        pool.ensure(1, 16)
    pool.grow(8)                          # 4 -> 8 pages
    assert pool.n_pages == 8 and pool.free_pages == 4
    assert (pool.table[0, :3] == mapped).all()   # mapping untouched
    pool.ensure(1, 64)                    # the new pages are allocatable
    pool.check_consistent()
    assert pool.live_pages == 7
    # warm reuse across growth: a just-freed old page is the next one out
    pool.free_slot(0)
    pool.ensure(1, 80)
    assert int(pool.table[1, 4]) == int(mapped[-1])
    pool.check_consistent()
    with pytest.raises(ValueError, match="grow"):
        pool.grow(8)                      # must strictly grow


def test_sharded_pool_grow_is_uniform():
    """Growth extends EVERY shard's block by the same count (the device
    pool's page axis must stay evenly partitioned) and keeps shard-local
    indices valid."""
    pool = PagePool(4, 4, 2, 8, n_shards=2)     # 1 usable page per shard
    pool.ensure(0, 8)
    pool.ensure(1, 8)
    with pytest.raises(PagePoolExhausted):
        pool.ensure(0, 16)
    pool.grow(4)
    assert pool.n_pages == 8 and pool.pages_per_shard == 4
    assert pool.shard_free_pages(0) == 2 and pool.shard_free_pages(1) == 2
    pool.ensure(0, 24)                          # grows within shard 0 only
    assert pool.shard_free_pages(0) == 0 and pool.shard_free_pages(1) == 2
    assert (pool.table[0, :3] < 4).all()        # local indices stay local
    pool.check_consistent()


def test_sharded_pool_validation():
    with pytest.raises(ValueError, match="divisible"):
        PagePool(7, 4, 2, 8, n_shards=2)
    with pytest.raises(ValueError, match="divisible"):
        PagePool(8, 4, 3, 8, n_shards=2)
    with pytest.raises(ValueError, match="trash"):
        PagePool(2, 4, 2, 8, n_shards=2)


def test_sharded_reserve_is_shard_local():
    """A hold on one shard must not block allocations on the other, and
    reserve checks the slot's OWN shard's free pages."""
    pool = PagePool(8, 4, 2, 8, n_shards=2)     # 3 usable pages per shard
    pool.reserve(0, 3)                          # shard 0 fully held
    assert pool.shard_free_pages(0) == 0 and pool.shard_free_pages(1) == 3
    pool.ensure(1, 24)                          # shard 1 unaffected
    with pytest.raises(PagePoolExhausted):
        pool.reserve(1, 1)                      # its own shard is dry
    pool.check_consistent()


def test_reservations_protect_inflight_prefills():
    """A hold placed at chunked admission is consumed by the holder's own
    allocations; other slots cannot dip into held stock, and free_pages
    (the admission gate) excludes outstanding holds."""
    pool = PagePool(4, 3, 2, 8)            # 3 usable pages
    pool.reserve(0, 2)
    assert pool.free_pages == 1 and pool.held_pages == 2
    pool.ensure(1, 8)                      # slot 1 takes the unheld page
    assert pool.free_pages == 0
    with pytest.raises(PagePoolExhausted):
        pool.ensure(1, 16)                 # may not eat slot 0's hold
    pool.check_consistent()                # failed alloc corrupted nothing
    pool.ensure(0, 16)                     # the holder consumes its hold
    assert int(pool.n_mapped[0]) == 2 and pool.held_pages == 0
    with pytest.raises(PagePoolExhausted):
        pool.reserve(1, 1)                 # nothing left to hold
    assert pool.free_slot(0) == 2          # retirement releases everything
    assert pool.free_pages == 2
    pool.check_consistent()
