"""Per-Pallas-kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode — the TPU target's semantics executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.flash_prefill import flash_attention_pallas
from repro.kernels.flash_prefill.ref import flash_attention_reference
from repro.kernels.swan_decode.swan_decode import swan_decode_pallas
from repro.kernels.swan_decode.ref import swan_decode_reference
from repro.kernels.swan_prune.swan_prune import swan_prune_pallas
from repro.kernels.swan_prune.ref import swan_prune_reference
from repro.core.projections import random_orthogonal


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _unique_idx(rng, B, Kv, S, k, dh):
    out = np.stack([rng.permutation(dh)[:k]
                    for _ in range(B * Kv * S)]).reshape(B, Kv, S, k)
    return jnp.asarray(out, jnp.int8)


TOL = {jnp.float32: 5e-5, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Kv,G,dh,S,k,b,bs", [
    (1, 1, 1, 16, 32, 4, 8, 16),
    (2, 2, 4, 32, 64, 8, 16, 32),
    (1, 2, 2, 64, 48, 16, 8, 16),    # non-pow2 block count
])
def test_swan_decode_kernel(dtype, B, Kv, G, dh, S, k, b, bs):
    rng = np.random.default_rng(0)
    q = _rand(rng, (B, Kv, G, dh), dtype)
    kv = _rand(rng, (B, Kv, S, k), dtype)
    vv = _rand(rng, (B, Kv, S, k), dtype)
    ki = _unique_idx(rng, B, Kv, S, k, dh)
    vi = _unique_idx(rng, B, Kv, S, k, dh)
    bk = _rand(rng, (B, Kv, b, dh), dtype)
    bv = _rand(rng, (B, Kv, b, dh), dtype)
    # per-sequence ring state: stagger positions across the batch
    bpos = jnp.asarray(np.stack(
        [np.concatenate([np.arange(40 - i, 40 - i + b - 2), [-1, -1]])
         for i in range(B)]), jnp.int32)
    pos = jnp.asarray([45 - i for i in range(B)], jnp.int32)
    sp = jnp.asarray([S - 10 - i for i in range(B)], jnp.int32)
    o_k = swan_decode_pallas(q, kv, ki, vv, vi, bk, bv, bpos, pos, sp,
                             block_s=bs)
    o_r = swan_decode_reference(q, kv, ki, vv, vi, bk, bv, bpos, pos, sp)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=TOL[dtype])


def test_swan_decode_kernel_quantized():
    rng = np.random.default_rng(1)
    B, Kv, G, dh, S, k, b = 1, 2, 2, 32, 32, 8, 8
    kv8 = jnp.asarray(rng.integers(-127, 128, (B, Kv, S, k)), jnp.int8)
    vv8 = jnp.asarray(rng.integers(-127, 128, (B, Kv, S, k)), jnp.int8)
    ks = jnp.asarray(rng.random((B, Kv, S)) * 0.1 + 0.01, jnp.float32)
    vs = jnp.asarray(rng.random((B, Kv, S)) * 0.1 + 0.01, jnp.float32)
    ki = _unique_idx(rng, B, Kv, S, k, dh)
    vi = _unique_idx(rng, B, Kv, S, k, dh)
    q = _rand(rng, (B, Kv, G, dh), jnp.float32)
    bk = _rand(rng, (B, Kv, b, dh), jnp.float32)
    bv = _rand(rng, (B, Kv, b, dh), jnp.float32)
    bpos = jnp.broadcast_to(jnp.asarray(np.arange(20, 20 + b), jnp.int32),
                            (B, b))
    o_k = swan_decode_pallas(q, kv8, ki, vv8, vi, bk, bv, bpos, 27, 18,
                             k_scale=ks, v_scale=vs, block_s=16)
    o_r = swan_decode_reference(q, kv8, ki, vv8, vi, bk, bv, bpos, 27, 18,
                                k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,H,Kv,dh,bq,bk", [
    (1, 32, 2, 2, 16, 16, 16),
    (2, 64, 4, 2, 32, 16, 32),     # GQA + rectangular blocks
    (1, 48, 6, 1, 16, 16, 16),     # MQA-ish, non-pow2 seq
])
def test_flash_prefill_kernel(dtype, B, Sq, H, Kv, dh, bq, bk):
    rng = np.random.default_rng(2)
    q = _rand(rng, (B, Sq, H, dh), dtype)
    k = _rand(rng, (B, Sq, Kv, dh), dtype)
    v = _rand(rng, (B, Sq, Kv, dh), dtype)
    o_k = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk)
    o_r = flash_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               atol=TOL[dtype], rtol=1e-2)


def test_flash_prefill_noncausal():
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 32, 2, 16), jnp.float32)
    k = _rand(rng, (1, 32, 2, 16), jnp.float32)
    v = _rand(rng, (1, 32, 2, 16), jnp.float32)
    o_k = flash_attention_pallas(q, k, v, causal=False, block_q=16, block_k=16)
    o_r = flash_attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=5e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Kv,S,dh,k,tile", [
    (1, 2, 16, 16, 4, 8),
    (2, 2, 32, 32, 12, 16),
])
def test_swan_prune_kernel(dtype, B, Kv, S, dh, k, tile):
    rng = np.random.default_rng(4)
    x = _rand(rng, (B, Kv, S, dh), dtype)
    P = random_orthogonal(jax.random.PRNGKey(0), (Kv,), dh)
    vk, ik = swan_prune_pallas(x, P, k, tile=tile)
    vr, ir = swan_prune_reference(x, P, k)
    assert bool(jnp.all(ik == ir)), "index selection must match lax.top_k"
    np.testing.assert_allclose(np.asarray(vk, np.float32),
                               np.asarray(vr, np.float32), atol=TOL[dtype])


def test_kernel_path_equals_core_path():
    """ops.py wrapper on a real hybrid cache == core swan attention."""
    from repro.configs import SwanConfig, get_smoke_config
    from repro.core import hybrid_cache as hc
    from repro.core import swan_attention as swa
    from repro.kernels.swan_decode.ops import swan_decode_attention_kernel

    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=4, mode="topk")
    key = jax.random.PRNGKey(0)
    kh = jax.random.normal(key, (2, 20, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.PRNGKey(1),
                           (2, 20, cfg.n_kv_heads, cfg.d_head))
    cache = hc.init_swan_cache(cfg, swan, 2, 32)
    cache = hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh)
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    o_core = swa.swan_decode_attention(q, cache, swan, cfg, 19)
    o_kern = swan_decode_attention_kernel(q, cache, swan, cfg, 19,
                                          block_s=16)
    np.testing.assert_allclose(np.asarray(o_core), np.asarray(o_kern),
                               atol=1e-5)
