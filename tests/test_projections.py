"""SWAN projection construction (paper §4.1): orthogonality, energy
ordering, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.projections import (check_orthogonal, compute_projections,
                                    gram_basis, layer_projections,
                                    random_orthogonal)


def test_gram_basis_orthogonal():
    s = jax.random.normal(jax.random.PRNGKey(0), (500, 64))
    p = gram_basis(s)
    assert float(check_orthogonal(p[None])) < 1e-3


def test_gram_basis_energy_descending():
    """Columns ordered by decreasing captured variance (enables truncation)."""
    key = jax.random.PRNGKey(1)
    # anisotropic data: descending energy must be recovered
    scales = jnp.linspace(10.0, 0.1, 32)
    s = jax.random.normal(key, (2000, 32)) * scales[None]
    p = gram_basis(s)
    energy = jnp.var(s @ p, axis=0)
    diffs = jnp.diff(energy)
    assert float(jnp.max(diffs)) < 1e-2


def test_gram_basis_matches_svd():
    s = np.random.default_rng(2).standard_normal((300, 16)).astype(np.float32)
    p = np.asarray(gram_basis(jnp.asarray(s)))
    _, _, vt = np.linalg.svd(s, full_matrices=True)
    # same subspace per column up to sign
    dots = np.abs(np.sum(p * vt.T, axis=0))
    np.testing.assert_allclose(dots, 1.0, atol=1e-2)


@pytest.mark.parametrize("n_heads,n_kv", [(4, 4), (8, 2), (6, 1)])
def test_layer_projections_shapes(n_heads, n_kv):
    dh, B, S, d = 16, 2, 24, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, n_heads, dh))
    k = jax.random.normal(key, (B, S, n_kv, dh))
    v = jax.random.normal(key, (B, S, n_kv, dh))
    wo = jax.random.normal(key, (n_heads * dh, d))
    p_qk, p_vo, e_qk, e_vo = layer_projections(q, k, v, wo, n_heads, n_kv, dh)
    assert e_qk.shape == (n_kv, dh)
    assert p_qk.shape == (n_kv, dh, dh)
    assert p_vo.shape == (n_kv, dh, dh)
    assert float(check_orthogonal(p_qk)) < 1e-3
    assert float(check_orthogonal(p_vo)) < 1e-3


def test_compute_projections_stacked_layers():
    L, B, S, H, Kv, dh, d = 3, 2, 16, 4, 2, 8, 32
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (L, B, S, H, dh))
    k = jax.random.normal(key, (L, B, S, Kv, dh))
    v = jax.random.normal(key, (L, B, S, Kv, dh))
    wo = jax.random.normal(key, (L, H * dh, d))
    pj = compute_projections((q, k, v), wo, H, Kv, dh)
    assert pj["p_qk"].shape == (L, Kv, dh, dh)
    assert float(check_orthogonal(pj["p_qk"])) < 1e-3


def test_random_orthogonal():
    p = random_orthogonal(jax.random.PRNGKey(0), (3, 2), 16)
    assert p.shape == (3, 2, 16, 16)
    assert float(check_orthogonal(p)) < 1e-4
