"""ServeSession end-to-end: batched generation, SWAN plumbing, memory
accounting, calibrate-absorb-serve pipeline via the public API."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.serve_loop import ServeSession, calibrate_swan


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = make_batch(cfg, 2, 24, seed=3)
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def test_generate_dense(setup):
    cfg, api, params, _, _ = setup
    sess = ServeSession(cfg, params, max_seq=64, batch=2)
    out = sess.generate(make_batch(cfg, 2, 12), 8)
    assert out.shape == (2, 8)
    assert out.dtype == jnp.int32


def test_swan_full_k_matches_dense_greedy(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")
    s1 = ServeSession(cfg, params, max_seq=64, batch=2)
    s2 = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                      max_seq=64, batch=2)
    prompt = make_batch(cfg, 2, 12)
    o1 = s1.generate(prompt, 10)
    o2 = s2.generate(prompt, 10)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_swan_compressed_generates(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head // 2, buffer=4, mode="topk",
                      quantize=True)
    sess = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                        max_seq=64, batch=2)
    out = sess.generate(make_batch(cfg, 2, 12), 10)
    assert out.shape == (2, 10)


def test_cache_report_savings(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head // 4, buffer=4, mode="topk",
                      quantize=True)
    sess = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                        max_seq=512, batch=2)
    rep = sess.cache_report()
    assert rep["saving"] > 0.5
    assert rep["bytes"] < rep["dense_bytes"]


def test_swan_requires_projections(setup):
    cfg, api, params, _, _ = setup
    with pytest.raises(ValueError, match="projections"):
        ServeSession(cfg, params, swan=SwanConfig(k_max=8, buffer=4),
                     max_seq=32, batch=1)


def test_sampled_generation_deterministic_per_seed(setup):
    cfg, api, params, _, _ = setup
    sess = ServeSession(cfg, params, max_seq=64, batch=2)
    prompt = make_batch(cfg, 2, 8)
    a = sess.generate(prompt, 5, temperature=1.0, seed=7)
    sess2 = ServeSession(cfg, params, max_seq=64, batch=2)
    b = sess2.generate(prompt, 5, temperature=1.0, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
