"""ServeSession end-to-end: batched generation, SWAN plumbing, memory
accounting, calibrate-absorb-serve pipeline via the public API — plus the
sampling-path regressions (PRNG key schedule, f32-before-temperature)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.launch.io import make_batch
from repro.models import get_model
from repro.runtime.sampling import sample_token
from repro.runtime.serve_loop import ServeSession, calibrate_swan


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    calib = make_batch(cfg, 2, 24, seed=3)
    pj = calibrate_swan(api, cfg, params, calib)
    absorbed = api.absorb(params, cfg, pj)
    return cfg, api, params, absorbed, pj


def test_generate_dense(setup):
    cfg, api, params, _, _ = setup
    sess = ServeSession(cfg, params, max_seq=64, batch=2)
    out = sess.generate(make_batch(cfg, 2, 12), 8)
    assert out.shape == (2, 8)
    assert out.dtype == jnp.int32


def test_swan_full_k_matches_dense_greedy(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head, buffer=8, mode="topk")
    s1 = ServeSession(cfg, params, max_seq=64, batch=2)
    s2 = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                      max_seq=64, batch=2)
    prompt = make_batch(cfg, 2, 12)
    o1 = s1.generate(prompt, 10)
    o2 = s2.generate(prompt, 10)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_swan_compressed_generates(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head // 2, buffer=4, mode="topk",
                      quantize=True)
    sess = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                        max_seq=64, batch=2)
    out = sess.generate(make_batch(cfg, 2, 12), 10)
    assert out.shape == (2, 10)


def test_cache_report_savings(setup):
    cfg, api, params, absorbed, pj = setup
    swan = SwanConfig(k_max=cfg.d_head // 4, buffer=4, mode="topk",
                      quantize=True)
    sess = ServeSession(cfg, absorbed, swan=swan, projections=pj,
                        max_seq=512, batch=2)
    rep = sess.cache_report()
    assert rep["saving"] > 0.5
    assert rep["bytes"] < rep["dense_bytes"]


def test_swan_requires_projections(setup):
    cfg, api, params, _, _ = setup
    with pytest.raises(ValueError, match="projections"):
        ServeSession(cfg, params, swan=SwanConfig(k_max=8, buffer=4),
                     max_seq=32, batch=1)


def test_sampled_generation_deterministic_per_seed(setup):
    cfg, api, params, _, _ = setup
    sess = ServeSession(cfg, params, max_seq=64, batch=2)
    prompt = make_batch(cfg, 2, 8)
    a = sess.generate(prompt, 5, temperature=1.0, seed=7)
    sess2 = ServeSession(cfg, params, max_seq=64, batch=2)
    b = sess2.generate(prompt, 5, temperature=1.0, seed=7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_key_schedule_splits_before_use(setup):
    """Regression for the use-then-split PRNG bug: the prefill-token sample
    must consume a key SPLIT from the root, never the root itself (which is
    then split again to derive every later draw — key reuse).  Replays the
    documented schedule draw by draw, which also pins the prefill sample's
    independence from later draws."""
    cfg, api, params, _, _ = setup
    prompt = make_batch(cfg, 2, 8)
    out = np.asarray(ServeSession(cfg, params, max_seq=64, batch=2)
                     .generate(prompt, 4, temperature=1.0, seed=11))
    sess = ServeSession(cfg, params, max_seq=64, batch=2)
    logits = sess.prefill(prompt)
    key = jax.random.PRNGKey(11)
    toks = []
    for i in range(4):
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, jnp.asarray(logits, jnp.float32), axis=-1).astype(jnp.int32)
        toks.append(np.asarray(tok))
        if i < 3:
            logits = sess.decode(tok)
    np.testing.assert_array_equal(out, np.stack(toks, axis=1))


def test_sampled_prefix_independent_of_horizon(setup):
    """Draw i depends only on (seed, i): generating longer must not change
    the earlier samples."""
    cfg, api, params, _, _ = setup
    prompt = make_batch(cfg, 2, 8)
    a = np.asarray(ServeSession(cfg, params, max_seq=64, batch=2)
                   .generate(prompt, 2, temperature=0.8, seed=5))
    b = np.asarray(ServeSession(cfg, params, max_seq=64, batch=2)
                   .generate(prompt, 6, temperature=0.8, seed=5))
    np.testing.assert_array_equal(a, b[:, :2])


def test_sample_token_casts_to_f32_before_temperature():
    """The shared sampler must divide f32 logits, not raw bf16: dividing in
    bf16 re-rounds the distribution and can flip near-tie draws.  Pin the
    contract (categorical over f32(logits)/T) across a battery of keys."""
    logits = jnp.asarray(
        np.linspace(90.0, 100.5, 32), jnp.bfloat16)[None]   # near-tie tail
    for s in range(50):
        key = jax.random.PRNGKey(s)
        want = jax.random.categorical(
            key, jnp.asarray(logits, jnp.float32) / 7.0, axis=-1)
        got = sample_token(logits, 7.0, key)
        assert int(got[0]) == int(want[0]), s
    # greedy path: argmax, key untouched
    assert int(sample_token(logits, 0.0, jax.random.PRNGKey(0))[0]) == 31
