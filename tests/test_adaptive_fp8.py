"""Beyond-paper extensions: adaptive per-layer k allocation + fp8 values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SwanConfig, get_smoke_config
from repro.core import hybrid_cache as hc
from repro.core import swan_attention as swa
from repro.core.adaptive import allocate_k, spectra_from_joint, uniform_k


def test_allocate_k_budget_and_bounds():
    rng = np.random.default_rng(0)
    spec = np.sort(rng.random((6, 32)), axis=1)[:, ::-1]
    spec = spec / spec.sum(1, keepdims=True)
    k = allocate_k(spec, avg_k=8, k_min=2, k_max=16)
    assert k.sum() == 8 * 6
    assert k.min() >= 2 and k.max() <= 16


def test_allocate_k_prefers_flat_spectra():
    """A flat-spectrum layer needs more dims than a concentrated one."""
    concentrated = np.zeros(32)
    concentrated[:2] = [0.9, 0.1]
    flat = np.full(32, 1 / 32)
    spec = np.stack([concentrated, flat])
    k = allocate_k(spec, avg_k=8, k_min=1, k_max=31)
    assert k[1] > k[0], k


def test_allocate_k_uniform_when_identical():
    spec = np.tile(np.linspace(1, 0.1, 16) / np.linspace(1, 0.1, 16).sum(),
                   (4, 1))
    k = allocate_k(spec, avg_k=6, k_min=1)
    assert abs(int(k.max()) - int(k.min())) <= 1


def test_spectra_from_joint():
    e = jnp.asarray(np.random.default_rng(1).random((3, 2, 16)))
    s = spectra_from_joint(e)
    assert s.shape == (3, 16)
    np.testing.assert_allclose(s.sum(axis=1), 1.0, atol=1e-6)


@pytest.mark.parametrize("mode", ["topk", "truncate"])
def test_fp8_values_match_reference(mode):
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32")
    swan = SwanConfig(k_max=8, buffer=4, mode=mode, quantize=True,
                      quant_dtype="fp8")
    cache = hc.init_swan_cache(cfg, swan, 2, 32)
    assert cache["k"]["vals"].dtype == jnp.float8_e4m3fn
    assert "scale" not in cache["k"]
    key = jax.random.PRNGKey(0)
    kh = jax.random.normal(key, (2, 20, cfg.n_kv_heads, cfg.d_head))
    vh = jax.random.normal(jax.random.PRNGKey(1),
                           (2, 20, cfg.n_kv_heads, cfg.d_head))
    cache = hc.swan_cache_insert_prefill(cache, swan, cfg, kh, vh)
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (2, cfg.n_kv_heads, cfg.q_group, cfg.d_head))
    o = swa.swan_decode_attention(q, cache, swan, cfg, 19)
    r = swa.swan_decode_attention_reference(q, cache, swan, cfg, 19)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-3)
    assert not bool(jnp.any(jnp.isnan(o)))


def test_fp8_eq1_bytes():
    """fp8 matches the paper's 2k+2-class budget (no scale array)."""
    cfg = get_smoke_config("llama3-8b")
    b_fp8 = hc.cache_bytes(cfg, SwanConfig(k_max=8, buffer=0, quantize=True,
                                           quant_dtype="fp8"), 1, 16)
    b_int8 = hc.cache_bytes(cfg, SwanConfig(k_max=8, buffer=0, quantize=True,
                                            quant_dtype="int8"), 1, 16)
    b_fp16 = hc.cache_bytes(cfg, SwanConfig(k_max=8, buffer=0), 1, 16)
    assert b_fp8 < b_int8 < b_fp16


def test_per_layer_k_end_to_end():
    """Adaptive allocation through prefill+decode == graceful, no NaN, and
    degrades less than the worst uniform layer choice."""
    from repro.models import transformer as tf
    from repro.core import projections as proj
    cfg = get_smoke_config("llama3-8b").replace(dtype="float32",
                                                param_dtype="float32")
    params = tf.init_lm_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0,
                                cfg.vocab_size)
    q, k, v, wo = tf.collect_qkv(params, cfg, tokens)
    pj = proj.compute_projections((q, k, v), wo, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.d_head)
    absorbed = tf.absorb_swan(params, cfg, pj)
    swan = SwanConfig(k_max=cfg.d_head, buffer=4, mode="topk")
    pj2 = dict(pj)
    pj2["k_layer"] = jnp.asarray([6, 10], jnp.int32)
    caches = tf.init_caches(cfg, swan, 2, 32)
    lg, caches = tf.lm_prefill(absorbed, cfg, tokens, caches, swan, pj2)
    tok = jnp.argmax(lg[:, -1], -1)
    lg, caches = tf.lm_decode_step(absorbed, cfg, tok, 20, caches, swan, pj2)
    assert not bool(jnp.any(jnp.isnan(lg)))
