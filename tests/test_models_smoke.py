"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus a serve (prefill + decode) smoke including SWAN."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ARCH_IDS, OptimizerConfig, SwanConfig,
                           get_config, get_smoke_config)
from repro.core import projections as proj
from repro.launch.io import make_batch
from repro.models import get_model, swan_applicable
from repro.optim.adamw import adamw_update, init_opt_state


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 16)

    logits, aux = api.forward(params, cfg, batch)
    expect_s = 16 + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN in logits"

    def loss_fn(p):
        return api.loss(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert not bool(jnp.isnan(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert gn > 0, "gradients are identically zero"
    opt = init_opt_state(params, OptimizerConfig())
    new_params, opt, metrics = adamw_update(params, grads, opt, OptimizerConfig())
    assert not bool(jnp.isnan(metrics["grad_norm"]))
    # params actually moved
    moved = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_smoke(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 12)
    state = api.init_serve_state(cfg, None, 2, 24)
    logits, state = api.prefill(params, cfg, batch, state)
    tok = jnp.argmax(logits[:, -1], -1)
    for i in range(3):
        logits, state = api.decode_step(params, cfg, tok, 12 + i, state)
        assert logits.shape == (2, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits, -1)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "rwkv6-3b"])
def test_swan_serve_smoke(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    assert swan_applicable(cfg)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 12)
    q, k, v, wo = api.collect_qkv(params, cfg, batch)
    pj = proj.compute_projections((q, k, v), wo, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.d_head)
    absorbed = api.absorb(params, cfg, pj)
    swan = SwanConfig(k_max=max(cfg.d_head // 2, 2), buffer=4, mode="topk")
    state = api.init_serve_state(cfg, swan, 2, 24)
    logits, state = api.prefill(absorbed, cfg, batch, state, swan, pj)
    tok = jnp.argmax(logits[:, -1], -1)
    for i in range(3):
        logits, state = api.decode_step(absorbed, cfg, tok, 12 + i, state,
                                        swan, pj)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits, -1)


def test_swan_rejected_for_rwkv():
    cfg = get_smoke_config("rwkv6-3b")
    api = get_model(cfg)
    assert not swan_applicable(cfg)
    with pytest.raises(ValueError, match="inapplicable"):
        api.init_serve_state(cfg, SwanConfig(k_max=4, buffer=2), 1, 8)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_parameter_counts(arch):
    """Analytical n_params of the FULL config lands near the published
    size (loose band — embeddings/heads differ across papers)."""
    published = {
        "deepseek-moe-16b": 16.4e9, "qwen2-moe-a2.7b": 14.3e9,
        "llama3-8b": 8.0e9, "olmo-1b": 1.2e9, "llama3-405b": 405e9,
        "yi-9b": 8.8e9, "internvl2-1b": 0.6e9,       # text backbone only
        "jamba-1.5-large-398b": 398e9, "whisper-small": 0.24e9,
        "rwkv6-3b": 3.1e9,
    }
    n = get_config(arch).n_params()
    assert 0.5 * published[arch] < n < 1.6 * published[arch], \
        f"{arch}: analytic {n/1e9:.2f}B vs published {published[arch]/1e9:.2f}B"
