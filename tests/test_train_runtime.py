"""Training runtime: convergence, grad-accum equivalence, preemption
checkpointing, straggler watchdog, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, TrainConfig, get_smoke_config
from repro.runtime.fault_tolerance import StepWatchdog
from repro.runtime.grad_compress import (compress_gradients,
                                         dp_int8_allreduce, residuals)
from repro.runtime.train_loop import Trainer, make_train_step
from repro.optim.adamw import init_opt_state
from repro.launch.io import make_batch


def test_loss_decreases(tmp_path):
    cfg = get_smoke_config("llama3-8b").replace(remat=False)
    tc = TrainConfig(model=cfg, seq_len=24, global_batch=8, steps=60,
                     optimizer=OptimizerConfig(lr=1e-2, warmup_steps=3,
                                               decay_steps=60),
                     checkpoint_dir=str(tmp_path), checkpoint_every=1000,
                     log_every=59)
    out = Trainer(tc).run()
    first, last = out["log"][0]["loss"], out["log"][-1]["loss"]
    # the synthetic language is 45% copy-task (slow induction learning);
    # the markov share alone gives a reliable drop by step 60 (measured
    # trajectory: 5.57 -> 4.6)
    assert last < first - 0.5, (first, last)


def test_grad_accum_equivalence():
    """accum=2 over a batch == accum=1 over the same batch (up to fp)."""
    cfg = get_smoke_config("olmo-1b").replace(
        remat=False, dtype="float32", param_dtype="float32")
    api_batch = make_batch(cfg, 4, 16)
    step1 = make_train_step(cfg, OptimizerConfig(), grad_accum=1)
    step2 = make_train_step(cfg, OptimizerConfig(), grad_accum=2)
    from repro.models import get_model
    params = get_model(cfg).init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, OptimizerConfig())
    p1, _, m1 = step1(params, opt, api_batch)
    p2, _, m2 = step2(params, opt, api_batch)
    # microbatch losses average to ~the same value; params should agree
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_preemption_triggers_checkpoint(tmp_path):
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    tc = TrainConfig(model=cfg, seq_len=16, global_batch=4, steps=50,
                     optimizer=OptimizerConfig(lr=1e-3),
                     checkpoint_dir=str(tmp_path), checkpoint_every=1000,
                     log_every=1, async_checkpoint=False)
    tr = Trainer(tc)
    tr.preemption.trigger()                      # simulate SIGTERM
    out = tr.run()
    assert out["step"] == 1                       # stopped at first step
    assert tr.ckpt.latest_step() == 1             # but saved its state


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=2.0, warmup_steps=1)
    for i in range(5):
        wd.record(i, 1.0)
    assert wd.record(5, 5.0) is True
    assert wd.stragglers[-1][0] == 5
    assert wd.record(6, 1.0) is False            # EMA not poisoned
    assert abs(wd.ema - 1.0) < 0.05


def test_grad_compression_roundtrip_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (1000,))}
    out = compress_gradients(g)
    err = jnp.max(jnp.abs(out["w"] - g["w"]))
    bound = jnp.max(jnp.abs(g["w"])) / 127.0
    assert float(err) <= float(bound) + 1e-6


def test_grad_compression_error_feedback():
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (512,))}
    r = residuals(g)
    # one more round with error feedback reduces bias: E[g + e] closer to g
    out = compress_gradients(g, error_feedback=r)
    plain = compress_gradients(g)
    err_fb = float(jnp.mean(jnp.abs(out["w"] - g["w"] - r["w"])))
    assert err_fb <= float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-6
    assert not bool(jnp.any(jnp.isnan(out["w"])))
    del plain


def test_dp_int8_allreduce_single_device():
    """On a 1-device mesh the compressed all-reduce reduces to the identity
    quant/dequant round."""
    from repro.launch.mesh import make_mesh
    from repro.sharding.api import shard_map_compat
    from jax.sharding import PartitionSpec as P
    mesh = make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 8))}

    def f(g):
        return dp_int8_allreduce(g, "data")

    out = jax.jit(shard_map_compat(f, mesh, (P(),), P()))(g)
    err = jnp.max(jnp.abs(out["w"] - g["w"]))
    assert float(err) <= float(jnp.max(jnp.abs(g["w"]))) / 127.0 + 1e-6
